"""Async actor/learner driver: decoupled IC3Net + FLGW training.

Actors run rollouts against the latest *published* ``(params, PlanState,
version)`` bundle and push the windows into a device-resident ring
buffer; the learner drains it, applying an off-policy correction
(``--correction vtrace`` by default) sized to the observed staleness.
Publication is plan-consistent: every bundle is certified against the
params' plan signature before actors may adopt it, so a grouped-path
actor never steps on a params/plan mismatch.

  PYTHONPATH=src python examples/marl_async.py --updates 64 --cadence 4
  PYTHONPATH=src python examples/marl_async.py --env traffic_junction \
      --groups 4 --path grouped --correction vtrace

Multi-host bring-up (one process per host; the coordinator address and
process ids may also come from JAX_COORDINATOR / JAX_NUM_PROCESSES /
JAX_PROCESS_ID env vars):

  PYTHONPATH=src python examples/marl_async.py --distributed \
      --coordinator host0:1234 --processes 2 --process-id 0 --batch 32

``--batch`` stays the GLOBAL env batch; each host feeds its
``host_local_batch`` slice. On backends without cross-process
collectives (CPU) the init degrades to a single process with a warning
unless ``--strict-distributed`` is set.
"""
import argparse

import numpy as np

from repro.marl import async_train as async_mod
from repro.marl import envs as envs_mod
from repro.marl import ic3net
from repro.marl import train as train_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="predator_prey",
                    choices=envs_mod.names())
    ap.add_argument("--agents", type=int, default=3)
    ap.add_argument("--size", type=int, default=4)
    ap.add_argument("--groups", type=int, default=1)
    ap.add_argument("--path", default="masked",
                    choices=("masked", "grouped"))
    ap.add_argument("--updates", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16,
                    help="GLOBAL env batch (split across hosts when "
                         "--distributed)")
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cadence", type=int, default=1,
                    help="actor rollout windows generated per learner "
                         "update (AsyncConfig.actors)")
    ap.add_argument("--correction", default="vtrace",
                    choices=async_mod.CORRECTIONS)
    ap.add_argument("--capacity", type=int, default=None,
                    help="trajectory-queue depth (default max(4, cadence))")
    ap.add_argument("--publish-every", type=int, default=1,
                    help="learner updates per params publication")
    ap.add_argument("--max-staleness", type=int, default=None,
                    help="evict queued windows older than this many "
                         "publications (default 2*cadence+2)")
    ap.add_argument("--threads", action="store_true",
                    help="run the actor on its own thread (real overlap, "
                         "nondeterministic interleaving)")
    ap.add_argument("--check-publication", action="store_true",
                    help="assert plan-signature consistency of every "
                         "published bundle")
    ap.add_argument("--log-every", type=int, default=0)
    ap.add_argument("--debug-contracts", action="store_true",
                    help="run under repro.analysis.contracts.no_retrace: "
                         "fail if actor/learner/publish recompile mid-run")
    ap.add_argument("--distributed", action="store_true",
                    help="initialise jax.distributed for multi-host runs")
    ap.add_argument("--coordinator", default=None,
                    help="coordinator host:port (or JAX_COORDINATOR)")
    ap.add_argument("--processes", type=int, default=None)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--strict-distributed", action="store_true",
                    help="fail instead of degrading to single-process "
                         "when distributed init cannot complete")
    args = ap.parse_args(argv)

    batch = args.batch
    if args.distributed:
        from repro.launch import mesh as mesh_lib
        info = mesh_lib.init_distributed(
            args.coordinator, args.processes, args.process_id,
            strict=args.strict_distributed)
        print(f"distributed: {info['distributed']} "
              f"process {info['process_index']}/{info['process_count']} "
              f"local_devices={info['local_devices']}")
        if info["distributed"]:
            batch, offset = mesh_lib.host_local_batch(args.batch)
            print(f"host-local batch {batch} (env offset {offset})")

    cfg = ic3net.IC3NetConfig(hidden=args.hidden, flgw_groups=args.groups,
                              flgw_path=args.path)
    env, ecfg = envs_mod.make(args.env, n_agents=args.agents,
                              size=args.size, max_steps=3 * args.size)
    tcfg = train_mod.TrainConfig(batch=batch)
    acfg = async_mod.AsyncConfig(
        capacity=args.capacity or max(4, args.cadence),
        actors=args.cadence, correction=args.correction,
        publish_every=args.publish_every,
        max_staleness=(args.max_staleness if args.max_staleness is not None
                       else 2 * args.cadence + 2))
    print(f"async IC3Net on {args.env} A={args.agents} hidden={args.hidden} "
          f"FLGW G={args.groups} ({args.path}) | cadence {acfg.actors} "
          f"capacity {acfg.capacity} correction {acfg.correction} "
          f"publish_every {acfg.publish_every} "
          f"max_staleness {acfg.max_staleness}")

    params, hist = async_mod.async_train(
        cfg, ecfg, tcfg, acfg, updates=args.updates, seed=args.seed,
        log_every=args.log_every or max(1, args.updates // 8), env=env,
        threads=args.threads, check_publication=args.check_publication,
        debug_contracts=args.debug_contracts)

    succ = np.array([h["success"] for h in hist])
    stale = np.array([h["staleness"] for h in hist])
    depth = np.array([h["queue_depth"] for h in hist])
    k = max(1, len(succ) // 8)
    print(f"success: first-{k} {succ[:k].mean():.3f}  "
          f"last-{k} {succ[-k:].mean():.3f}")
    print(f"staleness: mean {stale.mean():.2f} max {stale.max():.0f}  "
          f"queue depth: mean {depth.mean():.2f}")
    print(f"throughput: {hist[-1]['env_steps_per_s']:.0f} env-steps/s "
          f"(actor clock), {hist[-1]['updates_per_s']:.2f} updates/s "
          f"(learner clock)")
    return params, hist


if __name__ == "__main__":
    main()
