"""Quickstart: the paper's technique in 60 lines.

Builds one FLGW-pruned linear layer, shows the three execution paths
(dense / masked / grouped), the OSEL sparse metadata, and a few training
steps where the grouping matrices learn alongside the weights.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import flgw
from repro.core.osel import encode
from repro.optim.optimizers import rmsprop, rmsprop_init

M, N, G, B = 256, 512, 4, 32


def main():
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (M, N)) * M ** -0.5
    grouping = flgw.init_grouping(jax.random.fold_in(key, 1), M, N, G)
    ig, og = grouping["ig"], grouping["og"]
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, M))

    # --- the mask: O(M·N) index compares, never an IS @ OS matmul --------
    ig_idx, og_idx = flgw.grouping_indices(ig, og)
    sparsity = float(flgw.mask_sparsity(ig_idx, og_idx, groups=G))
    print(f"FLGW G={G}: actual sparsity {sparsity:.3f} "
          f"(expected {1 - 1 / G:.3f})")

    # --- OSEL sparse row memory: <= G tuples describe the whole mask -----
    mem = encode(ig_idx, og_idx, G)
    print(f"OSEL: {mem.bitvectors.shape[0]} cached bitvectors, "
          f"workloads {mem.workloads.tolist()} (sum {int(mem.workloads.sum())})")

    # --- three execution paths -------------------------------------------
    y_dense = x @ w
    y_masked = flgw.flgw_linear(x, w, ig, og,
                                flgw.FLGWConfig(groups=G, path="masked"))
    y_grouped = flgw.flgw_linear(x, w, ig, og,
                                 flgw.FLGWConfig(groups=G, path="grouped"))
    print(f"dense->masked delta {float(jnp.abs(y_dense - y_masked).mean()):.4f}"
          f" (masking changes the function)")
    slack = flgw.FLGWConfig().capacity_slack
    print(f"masked vs grouped max|err| "
          f"{float(jnp.abs(y_masked - y_grouped).max()):.2e} "
          f"(compact path: {G / slack ** 2:.2f}x fewer FLOPs at "
          f"slack {slack})")

    # --- the grouping matrices TRAIN (the 'fully learnable' part) --------
    cfg = flgw.FLGWConfig(groups=G, path="masked")
    params = {"w": w, "ig": ig, "og": og}
    target = jax.random.normal(jax.random.fold_in(key, 3), (B, N))
    opt = rmsprop_init(params)

    @jax.jit
    def step(params, opt):
        def loss(p):
            y = flgw.flgw_linear(x, p["w"], p["ig"], p["og"], cfg)
            return jnp.mean((y - target) ** 2)
        l, g = jax.value_and_grad(loss)(params)
        params, opt = rmsprop(params, g, opt, lr=1e-3)
        return params, opt, l

    for i in range(201):
        params, opt, l = step(params, opt)
        if i % 50 == 0:
            moved = float(jnp.abs(params["ig"] - ig).mean())
            print(f"step {i:4d} loss {float(l):.4f} |dIG| {moved:.4f}")
    print("grouping matrices received gradient and moved — mask is learned,"
          " not fixed")


if __name__ == "__main__":
    main()
