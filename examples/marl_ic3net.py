"""End-to-end driver: IC3Net on Predator-Prey with FLGW sparse training.

The paper's own workload (§IV-A): A cooperative predators, IC3Net policy
with gated communication, REINFORCE+value training with RMSprop lr=1e-3,
FLGW weight grouping at a chosen G. Prints the success-rate curve and the
sparsity actually realised by the learned grouping matrices.

  PYTHONPATH=src python examples/marl_ic3net.py --agents 4 --groups 4 \
      --iterations 200
"""
import argparse

import jax
import numpy as np

from repro.core import flgw
from repro.marl import env as env_mod
from repro.marl import ic3net
from repro.marl import train as train_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--size", type=int, default=4)
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--path", default="masked",
                    choices=("masked", "grouped"))
    ap.add_argument("--iterations", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = ic3net.IC3NetConfig(hidden=args.hidden, flgw_groups=args.groups,
                              flgw_path=args.path)
    ecfg = env_mod.EnvConfig(n_agents=args.agents, size=args.size,
                             vision=1, max_steps=3 * args.size)
    tcfg = train_mod.TrainConfig(batch=args.batch)
    print(f"IC3Net A={args.agents} hidden={args.hidden} "
          f"FLGW G={args.groups} ({args.path}) "
          f"-> expected sparsity {100 * (1 - 1 / max(args.groups, 1)):.1f}%")

    params, hist = train_mod.train(cfg, ecfg, tcfg, args.iterations,
                                   seed=args.seed,
                                   log_every=max(1, args.iterations // 10))
    succ = np.array([h["success"] for h in hist])
    k = max(1, len(succ) // 10)
    print(f"success: first-{k} {succ[:k].mean():.3f}  "
          f"last-{k} {succ[-k:].mean():.3f}")

    if args.groups > 1:
        # realised sparsity of each learned FLGW layer
        print("learned per-layer sparsity:")
        for name, p in params.items():
            if isinstance(p, dict) and "ig" in p:
                ig_idx, og_idx = flgw.grouping_indices(p["ig"], p["og"])
                s = float(flgw.mask_sparsity(ig_idx, og_idx,
                                             groups=args.groups))
                print(f"  {name:<8} {100 * s:.1f}%")


if __name__ == "__main__":
    main()
