"""End-to-end driver: IC3Net + FLGW sparse training on any registered env.

The paper's own workload (§IV-A) is Predator-Prey; ``--env`` selects any
scenario from the ``repro.marl.envs`` registry (Traffic Junction and
cooperative-navigation Spread ship alongside it). Training runs fully on
device — whole log windows execute as one ``jax.lax.scan`` — with optional
dense warmup before the FLGW mask switches on (``--warmup``) and optional
scale-out over a 2-D ``(env, agent)`` ``jax.sharding`` mesh (``--mesh``;
the old ``--parallel`` pmap switch survives as a deprecated alias).
Prints the mesh sharding spec, the success-rate curve and the sparsity
actually realised by the learned grouping matrices.

  PYTHONPATH=src python examples/marl_ic3net.py --env traffic_junction \
      --agents 4 --groups 4 --iterations 200
  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
      python examples/marl_ic3net.py --mesh 2,2 --agents 4 --batch 16
"""
import argparse

import numpy as np

from repro.core import flgw
from repro.core.schedule import SparsitySchedule
from repro.marl import envs as envs_mod
from repro.marl import ic3net
from repro.marl import train as train_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="predator_prey",
                    choices=envs_mod.names())
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--size", type=int, default=4)
    ap.add_argument("--groups", type=int, default=4)
    ap.add_argument("--path", default="masked",
                    choices=("masked", "grouped"))
    ap.add_argument("--iterations", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--warmup", type=int, default=0,
                    help="train dense for this many iterations before "
                         "enabling the FLGW mask")
    ap.add_argument("--refresh", type=int, default=1,
                    help="re-encode the grouped path's plan cache every k "
                         "iterations (OSEL amortization; 1 = every step)")
    ap.add_argument("--refresh-mode", default="period",
                    choices=("period", "on_change", "hybrid"),
                    help="plan-refresh policy: fixed period, or "
                         "change-driven from the ig/og argmax hash "
                         "(repro.core.encoder)")
    ap.add_argument("--mesh", default=None,
                    help="ENV,AGENT shard counts of the jax.sharding mesh "
                         "path (e.g. 2,2); 'auto' puts every local device "
                         "on the env axis. --batch stays the GLOBAL env "
                         "batch. Replaces --parallel.")
    ap.add_argument("--parallel", action="store_true",
                    help="DEPRECATED: routes to --mesh auto (the old pmap "
                         "path is retired)")
    ap.add_argument("--log-every", type=int, default=0,
                    help="log-window length (0 = iterations/10); the scan "
                         "path runs one on-device window per log line")
    ap.add_argument("--host-loop", action="store_true",
                    help="drive one update per host iteration (seed loop) "
                         "instead of the on-device scan")
    args = ap.parse_args(argv)

    cfg = ic3net.IC3NetConfig(hidden=args.hidden, flgw_groups=args.groups,
                              flgw_path=args.path)
    env, ecfg = envs_mod.make(args.env, n_agents=args.agents,
                              size=args.size, max_steps=3 * args.size)
    mesh_shape = None
    if args.mesh:
        from repro.launch.mesh import parse_marl_mesh
        try:
            mesh_shape = ((0, 1) if args.mesh == "auto"
                          else parse_marl_mesh(args.mesh))
        except ValueError as e:
            ap.error(str(e))
    tcfg = train_mod.TrainConfig(batch=args.batch, parallel=args.parallel,
                                 mesh=mesh_shape)
    if mesh_shape is not None:
        from repro.launch.mesh import describe_marl_mesh, make_marl_mesh
        print(describe_marl_mesh(
            make_marl_mesh(env=mesh_shape[0], agent=mesh_shape[1]),
            batch=args.batch, n_agents=args.agents))
    schedule = SparsitySchedule(groups=args.groups,
                                warmup_steps=args.warmup,
                                refresh_every=args.refresh,
                                refresh=args.refresh_mode) \
        if (args.warmup or args.refresh > 1
            or args.refresh_mode != "period") else None
    print(f"IC3Net on {args.env} A={args.agents} hidden={args.hidden} "
          f"FLGW G={args.groups} ({args.path}) "
          f"-> expected sparsity {100 * (1 - 1 / max(args.groups, 1)):.1f}%"
          + (f", dense warmup {args.warmup} iters" if args.warmup else ""))

    params, hist = train_mod.train(
        cfg, ecfg, tcfg, args.iterations, seed=args.seed,
        log_every=args.log_every or max(1, args.iterations // 10), env=env,
        schedule=schedule, host_loop=args.host_loop)
    succ = np.array([h["success"] for h in hist])
    k = max(1, len(succ) // 10)
    print(f"success: first-{k} {succ[:k].mean():.3f}  "
          f"last-{k} {succ[-k:].mean():.3f}")
    # throughput from inside the scan (skip the compile-heavy first window)
    tail = hist[len(hist) // 2:]
    print(f"throughput: {np.mean([h['steps_per_s'] for h in tail]):.2f} "
          f"iters/s, {np.mean([h['env_steps_per_s'] for h in tail]):.0f} "
          f"env-steps/s, est. sparse "
          f"{np.mean([h['sparse_gflops'] for h in tail]):.3f} GFLOPS")

    if args.groups > 1:
        # realised sparsity of each learned FLGW layer
        print("learned per-layer sparsity:")
        for name, p in params.items():
            if isinstance(p, dict) and "ig" in p:
                ig_idx, og_idx = flgw.grouping_indices(p["ig"], p["og"])
                s = float(flgw.mask_sparsity(ig_idx, og_idx,
                                             groups=args.groups))
                print(f"  {name:<8} {100 * s:.1f}%")


if __name__ == "__main__":
    main()
