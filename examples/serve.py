"""Serve a small model through the unified ``repro.serving`` tier.

One :class:`~repro.serving.ServeSession` owns the params version, the
jitted steps and the plan policy; a :class:`~repro.serving.Engine`
schedules requests over a per-slot decode cache. Two disciplines:

* ``--mode lockstep``   — static batching: requests admit only into an
  all-free engine and the batch runs to its slowest member (the fig13
  baseline, now expressed as an admission policy).
* ``--mode continuous`` — continuous batching: requests join and leave
  the decode batch mid-flight; a freed slot takes a fresh prefill while
  its neighbours keep decoding.

On the FLGW grouped path (``--path grouped``) the session resolves the
sparse metadata (a ``PlanState``) once per params version through the
process-wide plan cache and every request shares it — the serving
analogue of the paper's encode-once OSEL dataflow. ``--plan-policy``
picks certification semantics (``certify`` | ``trust`` | ``off``).

  PYTHONPATH=src python examples/serve.py --arch gemma2_2b --batch 4 \
      --prompt-len 64 --gen 32 [--groups 4 --path grouped \
      --targets mlp,attn] [--mode continuous --requests 16 --p-arrive 0.5]
"""
import argparse

import jax
import numpy as np

from repro.configs import registry
from repro.core import encoder
from repro.models import transformer
from repro.serving import (Engine, Request, ServeSession, plan_cache,
                           synthetic_requests)
from repro.serving.stream import max_seq_for


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--batch", type=int, default=4,
                    help="engine capacity (decode-batch slots)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--groups", type=int, default=1)
    ap.add_argument("--path", default="masked",
                    choices=("masked", "grouped"),
                    help="FLGW execution path when --groups > 1")
    ap.add_argument("--targets", default="mlp",
                    help="comma-separated FLGW targets (mlp,attn,ssm,moe)")
    ap.add_argument("--mode", default="lockstep",
                    choices=("lockstep", "continuous"))
    ap.add_argument("--plan-policy", default="certify",
                    choices=("certify", "trust", "off"))
    ap.add_argument("--requests", type=int, default=0,
                    help="continuous mode: open-loop stream size "
                         "(default 4x batch)")
    ap.add_argument("--p-arrive", type=float, default=0.5,
                    help="continuous mode: Geometric arrival probability")
    ap.add_argument("--debug-contracts", action="store_true",
                    help="run under repro.analysis.contracts.no_retrace: "
                         "fail if any jitted step recompiles mid-run")
    args = ap.parse_args(argv)

    overrides = {}
    if args.groups > 1:
        overrides = {"flgw_groups": args.groups, "flgw_path": args.path,
                     "flgw_targets": tuple(args.targets.split(","))}
    cfg = registry.get_smoke_config(args.arch, **overrides)
    key = jax.random.PRNGKey(0)
    params, _ = transformer.lm_init(key, cfg)

    session = ServeSession(cfg, params, plan_policy=args.plan_policy,
                           debug_contracts=args.debug_contracts)
    if isinstance(session.plans, encoder.PlanState):
        n_plans = sum(1 for _ in encoder.iter_flgw_layers(params))
        print(f"serving plan-aware: PlanState with {n_plans} cached "
              f"GroupPlans shared via the process plan cache "
              f"(G={cfg.flgw_groups}, targets={cfg.flgw_targets}, "
              f"plan_policy={args.plan_policy})")

    if args.mode == "lockstep":
        # fixed batch, identical shapes — the classic serve loop, expressed
        # as lockstep admission over the same engine
        prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                     (args.batch, args.prompt_len),
                                     0, cfg.vocab)
        requests = [Request(rid=i, prompt=np.asarray(prompts[i]),
                            max_new_tokens=args.gen, arrival=0)
                    for i in range(args.batch)]
    else:
        n = args.requests or 4 * args.batch
        requests = synthetic_requests(
            1, n, vocab=cfg.vocab, p_arrive=args.p_arrive,
            prompt_len=(max(1, args.prompt_len // 2), args.prompt_len),
            gen_len=(max(1, args.gen // 2), args.gen))

    engine = Engine(session, capacity=args.batch,
                    max_seq=max_seq_for(requests), admission=args.mode)
    report = engine.run(requests)

    s = report.summary()
    print(f"{args.mode}: {s['requests']} requests, "
          f"{s['generated_tokens']} tokens in {s['wall_s']:.2f}s "
          f"({s['tokens_per_s']:.1f} tok/s, "
          f"{100 * s['slot_utilization']:.0f}% slot utilization, "
          f"{report.steps} steps)")
    if s["p50_s"] is not None:
        print(f"latency: p50 {s['p50_s'] * 1e3:.0f}ms / "
              f"p99 {s['p99_s'] * 1e3:.0f}ms "
              f"(p50 {s['p50_ticks']:.0f} / p99 {s['p99_ticks']:.0f} steps)")
    pc = plan_cache.stats()
    if pc["hits"] or pc["misses"]:
        print(f"plan cache: {pc['encodes']} encode(s), {pc['hits']} hit(s) "
              f"across {s['requests']} requests")
    done = [r for r in report.records if r.completed >= 0]
    if done:
        print(f"sample generated ids (req {done[0].rid}): "
              f"{done[0].tokens[:16]}")


if __name__ == "__main__":
    main()
