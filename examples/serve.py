"""Serve a small model with batched requests: prefill + decode loop.

Demonstrates the serving path the decode shape cells exercise: a batch of
prompts is prefilled (cache-free forward -> first token), then decoded
token by token through the ring-buffer KV/SSM caches. Reports per-phase
throughput.

  PYTHONPATH=src python examples/serve.py --arch gemma2_2b --batch 4 \
      --prompt-len 64 --gen 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import transformer
from repro.train import step as step_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--groups", type=int, default=1)
    args = ap.parse_args(argv)

    overrides = {"flgw_groups": args.groups} if args.groups > 1 else {}
    cfg = registry.get_smoke_config(args.arch, **overrides)
    key = jax.random.PRNGKey(0)
    params, _ = transformer.lm_init(key, cfg)
    b, p_len = args.batch, args.prompt_len
    max_seq = p_len + args.gen

    prompts = jax.random.randint(jax.random.fold_in(key, 1), (b, p_len),
                                 0, cfg.vocab, jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(p_len, dtype=jnp.int32),
                                 (b, p_len))

    # --- prefill: write the prompt into the cache token-group by group ---
    # (simple reference serving loop: replay prompt through the decode path
    #  so windowed ring buffers stay exact; a production server would batch
    #  chunked prefill — see launch/dryrun.py's prefill cells)
    serve = jax.jit(step_lib.make_serve_step(cfg))
    cache = transformer.init_cache(cfg, b, max_seq)
    if cfg.encoder_layers:
        cache["encoder_out"] = jnp.zeros((b, cfg.num_frames, cfg.d_model),
                                         cfg.dtype)
    t0 = time.time()
    tok = prompts[:, :1]
    for t in range(p_len):
        nxt, cache = serve(params, cache, prompts[:, t:t + 1],
                           positions[:, t:t + 1])
    jax.block_until_ready(nxt)
    t_prefill = time.time() - t0
    print(f"prefill: {b}x{p_len} tokens in {t_prefill:.2f}s "
          f"({b * p_len / t_prefill:.1f} tok/s)")

    # --- decode ----------------------------------------------------------
    t0 = time.time()
    tok = nxt
    out = [tok]
    for i in range(args.gen - 1):
        pos = jnp.full((b, 1), p_len + i, jnp.int32)
        tok, cache = serve(params, cache, tok, pos)
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decode: {b}x{args.gen} tokens in {t_dec:.2f}s "
          f"({b * args.gen / t_dec:.1f} tok/s)")
    print(f"sample generated ids (req 0): {gen[0, :16].tolist()}")


if __name__ == "__main__":
    main()
