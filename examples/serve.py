"""Serve a small model with batched requests: prefill + decode loop.

Demonstrates the serving path the decode shape cells exercise: a batch of
prompts is prefilled (cache-free forward -> first token), then decoded
token by token through the ring-buffer KV/SSM caches. Reports per-phase
throughput.

On the FLGW grouped path (``--path grouped``) the serving contract is
plan-aware: ``transformer.init_cache(..., params=params)`` encodes the
sparse metadata (a ``repro.core.encoder.PlanState``) once and caches it
*beside* the KV/SSM buffers; every prefill/decode step then runs the
grouped Pallas kernel against that amortized metadata instead of
re-encoding per projection per token.

  PYTHONPATH=src python examples/serve.py --arch gemma2_2b --batch 4 \
      --prompt-len 64 --gen 32 [--groups 4 --path grouped \
      --targets mlp,attn]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import encoder
from repro.models import transformer
from repro.train import step as step_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--groups", type=int, default=1)
    ap.add_argument("--path", default="masked",
                    choices=("masked", "grouped"),
                    help="FLGW execution path when --groups > 1")
    ap.add_argument("--targets", default="mlp",
                    help="comma-separated FLGW targets (mlp,attn,ssm,moe)")
    args = ap.parse_args(argv)

    overrides = {}
    if args.groups > 1:
        overrides = {"flgw_groups": args.groups, "flgw_path": args.path,
                     "flgw_targets": tuple(args.targets.split(","))}
    cfg = registry.get_smoke_config(args.arch, **overrides)
    key = jax.random.PRNGKey(0)
    params, _ = transformer.lm_init(key, cfg)
    b, p_len = args.batch, args.prompt_len
    max_seq = p_len + args.gen

    prompts = jax.random.randint(jax.random.fold_in(key, 1), (b, p_len),
                                 0, cfg.vocab, jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(p_len, dtype=jnp.int32),
                                 (b, p_len))

    # --- prefill: write the prompt into the cache token-group by group ---
    # (simple reference serving loop: replay prompt through the decode path
    #  so windowed ring buffers stay exact; a production server would batch
    #  chunked prefill — see launch/dryrun.py's prefill cells)
    serve = jax.jit(step_lib.make_serve_step(cfg))
    # Plan-aware cache: on the grouped path this encodes the PlanState once
    # and parks it beside the KV/SSM buffers for every step below.
    cache = transformer.init_cache(cfg, b, max_seq, params=params)
    if isinstance(cache["plans"], encoder.PlanState):
        n_plans = sum(1 for _ in encoder.iter_flgw_layers(params))
        print(f"serving plan-aware: PlanState with {n_plans} cached "
              f"GroupPlans rides the cache (G={cfg.flgw_groups}, "
              f"targets={cfg.flgw_targets})")
    if cfg.encoder_layers:
        cache["encoder_out"] = jnp.zeros((b, cfg.num_frames, cfg.d_model),
                                         cfg.dtype)
    t0 = time.time()
    tok = prompts[:, :1]
    for t in range(p_len):
        nxt, cache = serve(params, cache, prompts[:, t:t + 1],
                           positions[:, t:t + 1])
    jax.block_until_ready(nxt)
    t_prefill = time.time() - t0
    print(f"prefill: {b}x{p_len} tokens in {t_prefill:.2f}s "
          f"({b * p_len / t_prefill:.1f} tok/s)")

    # --- decode ----------------------------------------------------------
    t0 = time.time()
    tok = nxt
    out = [tok]
    for i in range(args.gen - 1):
        pos = jnp.full((b, 1), p_len + i, jnp.int32)
        tok, cache = serve(params, cache, tok, pos)
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decode: {b}x{args.gen} tokens in {t_dec:.2f}s "
          f"({b * args.gen / t_dec:.1f} tok/s)")
    print(f"sample generated ids (req 0): {gen[0, :16].tolist()}")


if __name__ == "__main__":
    main()
