"""Train a ~100M-class LM for a few hundred steps with FLGW sparsity.

Uses the launcher end to end: mesh from the local devices, sharded init,
deterministic data pipeline, fault-tolerant step runner with checkpoints.
The default config is a deepened gemma2-family smoke model (~tens of M
params — sized for the CPU container; on TPU pass --full).

  PYTHONPATH=src python examples/lm_train.py --steps 200 --groups 4
"""
import argparse

from repro.launch.train import train_lm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--groups", type=int, default=1)
    ap.add_argument("--path", default="masked",
                    choices=("masked", "grouped"))
    ap.add_argument("--refresh", type=int, default=1,
                    help="re-encode the grouped plan cache every k steps")
    ap.add_argument("--refresh-mode", default="period",
                    choices=("period", "on_change", "hybrid"),
                    help="plan-refresh policy (repro.core.encoder)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--full", action="store_true",
                    help="full published config (TPU-scale)")
    args = ap.parse_args(argv)

    train_lm(args.arch, smoke=not args.full, steps=args.steps,
             batch=args.batch, seq=args.seq, flgw_groups=args.groups,
             flgw_path=args.path, refresh_every=args.refresh,
             refresh=args.refresh_mode, ckpt_dir=args.ckpt_dir,
             save_every=max(10, args.steps // 4),
             log_every=max(1, args.steps // 20))


if __name__ == "__main__":
    main()
