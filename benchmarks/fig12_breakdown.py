"""Fig. 12 — execution-time breakdown: sparse-data generation vs DNN compute.

The paper's point: on GPU, mask generation + masking costs ~31 % of step
time; with OSEL on-chip it is ~2.9 % — and "sparse data generation and
weight compression are shared among the training batch samples, so the
portion of DNN computation becomes dominant" (§IV-E). We measure the same
two quantities for the TPU-path implementation:

  * encode+plan — the OSEL analogue (index extraction + capacity-balanced
    plan), computed ONCE per iteration regardless of batch;
  * compute — the FLGW grouped matmul stack, scaling with batch.

and report the generation share as the batch grows (the paper's fixed
G sweep is the B=32 column), plus the share under mask-refresh
amortization (core/schedule.py's refresh_every knob).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, save, timeit
from repro.core.flgw import FLGWConfig, init_grouping
from repro.core.grouped import grouped_apply, make_plan

M = N = 1024
LAYERS = 4


def main() -> dict:
    key = jax.random.PRNGKey(0)
    out = {"cells": []}
    row("# fig12_breakdown: OSEL-analogue generation share of one step")
    row("G", "batch", "encode_plan_us", "compute_us", "share_%",
        "share_refresh4_%")
    for g in (2, 4, 16):
        gm = [init_grouping(jax.random.fold_in(key, i * 10 + g), M, N, g)
              for i in range(LAYERS)]
        ws = [jax.random.normal(jax.random.fold_in(key, 99 + i), (M, N))
              for i in range(LAYERS)]
        cfg = FLGWConfig(groups=g, path="grouped")

        igs = [m["ig"] for m in gm]
        ogs = [m["og"] for m in gm]
        plan_fn = jax.jit(lambda igs, ogs: [make_plan(i, o)
                                            for i, o in zip(igs, ogs)])
        t_plan = timeit(plan_fn, igs, ogs)

        def fwd(x):
            h = x
            for w, m in zip(ws, gm):
                h = jnp.tanh(grouped_apply(h, w, m["ig"], m["og"], cfg))
            return h

        for batch in (1, 8, 32):
            x = jax.random.normal(jax.random.fold_in(key, batch), (batch, M))
            t_comp = timeit(jax.jit(fwd), x)
            share = 100.0 * t_plan / (t_plan + t_comp)
            share4 = 100.0 * (t_plan / 4) / (t_plan / 4 + t_comp)
            row(g, batch, f"{t_plan * 1e6:.1f}", f"{t_comp * 1e6:.1f}",
                f"{share:.1f}", f"{share4:.1f}")
            out["cells"].append({"G": g, "batch": batch,
                                 "encode_plan_s": t_plan,
                                 "compute_s": t_comp, "share_pct": share,
                                 "share_refresh4_pct": share4})
    row("# paper: GPU ~31% sparse-gen share; LearningGroup (OSEL) ~2.9%,")
    row("# falling further as batch grows — same trend here.")
    save("fig12_breakdown", out)
    return out


if __name__ == "__main__":
    main()
