"""Fig. 12 — execution-time breakdown: sparse-data generation vs DNN compute.

The paper's point: on GPU, mask generation + masking costs ~31 % of step
time; with OSEL on-chip it is ~2.9 % — and "sparse data generation and
weight compression are shared among the training batch samples, so the
portion of DNN computation becomes dominant" (§IV-E). We measure the same
two quantities for the TPU-path implementation:

  * encode+plan — the OSEL analogue (index extraction + capacity-balanced
    plan), computed ONCE per iteration regardless of batch;
  * compute — the FLGW grouped matmul stack, scaling with batch.

and report the generation share as the batch grows (the paper's fixed
G sweep is the B=32 column), plus the share under mask-refresh
amortization (core/schedule.py's refresh_every knob).

The second section *measures* that amortization end to end: a jitted
K-step training scan over a recurrent FLGW stack, comparing the plan
cache carried through the scan and re-encoded every ``refresh_every``
steps (``maybe_refresh_plans``-style ``lax.cond``) against the per-call
fallback that re-derives the plan inside every projection of the
unrolled T-step forward — the paper's GPU-baseline placement.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (row, save, timeit, timeit_interleaved,
                               write_bench_json)
from repro.core.flgw import FLGWConfig, init_grouping
from repro.core.grouped import grouped_apply, make_plan

M = N = 1024
LAYERS = 4


def main() -> dict:
    key = jax.random.PRNGKey(0)
    out = {"cells": []}
    row("# fig12_breakdown: OSEL-analogue generation share of one step")
    row("G", "batch", "encode_plan_us", "compute_us", "share_%",
        "share_refresh4_%")
    for g in (2, 4, 16):
        gm = [init_grouping(jax.random.fold_in(key, i * 10 + g), M, N, g)
              for i in range(LAYERS)]
        ws = [jax.random.normal(jax.random.fold_in(key, 99 + i), (M, N))
              for i in range(LAYERS)]
        cfg = FLGWConfig(groups=g, path="grouped")

        igs = [m["ig"] for m in gm]
        ogs = [m["og"] for m in gm]
        plan_fn = jax.jit(lambda igs, ogs: [make_plan(i, o)
                                            for i, o in zip(igs, ogs)])
        t_plan = timeit(plan_fn, igs, ogs)

        def fwd(x):
            h = x
            for w, m in zip(ws, gm):
                h = jnp.tanh(grouped_apply(h, w, m["ig"], m["og"], cfg))
            return h

        for batch in (1, 8, 32):
            x = jax.random.normal(jax.random.fold_in(key, batch), (batch, M))
            t_comp = timeit(jax.jit(fwd), x)
            share = 100.0 * t_plan / (t_plan + t_comp)
            share4 = 100.0 * (t_plan / 4) / (t_plan / 4 + t_comp)
            row(g, batch, f"{t_plan * 1e6:.1f}", f"{t_comp * 1e6:.1f}",
                f"{share:.1f}", f"{share4:.1f}")
            out["cells"].append({"G": g, "batch": batch,
                                 "encode_plan_s": t_plan,
                                 "compute_s": t_comp, "share_pct": share,
                                 "share_refresh4_pct": share4})
    row("# paper: GPU ~31% sparse-gen share; LearningGroup (OSEL) ~2.9%,")
    row("# falling further as batch grows — same trend here.")
    out["amortization"] = amortization()
    save("fig12_breakdown", out)
    am = out["amortization"]
    write_bench_json("fig12_breakdown", {
        "config": {"layers": LAYERS, "m": M, "n": N},
        "results": {"cells": out["cells"], "amortization": am},
        "acceptance": {
            "refresh4_beats_per_call": am["refresh_4"]["speedup"] > 1.0,
            "on_change_beats_tracking_fixed":
                bool(am["on_change_beats_tracking_fixed"]),
            # the paper's ~2.9% OSEL share lands here too for the
            # production-shaped G (G<=4 cells stay single-digit)...
            "encode_share_single_digit_below_g16": all(
                c["share_pct"] < 10.0 for c in out["cells"]
                if c["G"] <= 4),
            # ...and where compute genuinely scales with batch on this
            # host (G=16: the compact matmul dominates dispatch), the
            # share falls as batch grows, the paper's Fig 12 trend
            "share_falls_with_batch_at_g16":
                next(c for c in out["cells"]
                     if c["G"] == 16 and c["batch"] == 32)["share_pct"]
                < next(c for c in out["cells"]
                       if c["G"] == 16 and c["batch"] == 1)["share_pct"],
        }})
    return out


def amortization(m: int = 256, layers: int = 4, batch: int = 1,
                 t_steps: int = 1, k_steps: int = 64, g: int = 16) -> dict:
    """Measured per-step time of plan-amortized vs per-call grouped training.

    One jitted chunk = ``k_steps`` training iterations in a ``lax.scan``;
    each computes grads of a ``t_steps``-long forward through ``layers``
    FLGW layers and SGD-updates weights *and* grouping matrices. The
    grouping matrices follow the paper's **churn-then-freeze** dynamics:
    for a short head of the chunk (1/16 of it — the paper's masks settle
    within the first few percent of training) a per-step perturbation
    keeps flipping argmaxes, then the grouping updates stop (masks
    freeze) — the regime the change-driven refresh is built for. Variants:

    * ``per_call``  — plan=None: re-encoded inside every projection
                      (L encodes per iteration);
    * ``refresh_k`` — PlanState carried through the scan, re-encoded via
                      ``lax.cond`` every k iterations (L/k encodes per
                      iteration, the fixed-period OSEL amortization);
    * ``on_change`` — the argmax-hash carry, driven through the real
                      subsystem (``encoder.maybe_refresh`` with a
                      ``refresh="on_change"`` schedule): re-encode only on
                      steps whose signature changed (every churn step, no
                      freeze step).

    Runs on the jnp reference lowering of the grouped kernel (identical
    math; interpret-mode Pallas on CPU would inflate the compute term and
    bury the encode share the measurement is about).
    """
    from repro.core import encoder
    from repro.core.schedule import SparsitySchedule

    key = jax.random.PRNGKey(42)
    cfg = FLGWConfig(groups=g, path="grouped")
    on_change_sched = SparsitySchedule(groups=g, refresh="on_change")
    churn_steps = max(1, k_steps // 32)
    gm = [init_grouping(jax.random.fold_in(key, i), m, m, g)
          for i in range(layers)]
    igs = [p["ig"] for p in gm]
    ogs = [p["og"] for p in gm]
    ws = [jax.random.normal(jax.random.fold_in(key, 10 + i), (m, m)) * 0.1
          for i in range(layers)]
    x = jax.random.normal(jax.random.fold_in(key, 99), (batch, m))

    def gm_tree(igs, ogs):
        return {f"{i:02d}": {"ig": a, "og": b}
                for i, (a, b) in enumerate(zip(igs, ogs))}

    def loss(ws, igs, ogs, plans):
        def body(h, _):
            for i in range(layers):
                pl = None if plans is None else plans[i]
                h = jnp.tanh(grouped_apply(h, ws[i], igs[i], ogs[i], cfg,
                                           plan=pl))
            return h, None
        h, _ = jax.lax.scan(body, x, None, length=t_steps)
        return jnp.mean(h ** 2)

    def chunk(refresh):
        def run(ws, igs, ogs, plans, sig):
            def body(carry, it):
                ws, igs, ogs, plans, sig = carry

                def fresh():
                    return [make_plan(ig, og, cfg.capacity_slack)
                            for ig, og in zip(igs, ogs)]

                if refresh == "on_change":
                    state = encoder.PlanState(
                        {f"{i:02d}": p for i, p in enumerate(plans)}, sig)
                    state = encoder.maybe_refresh(
                        gm_tree(igs, ogs), state, it, cfg, on_change_sched)
                    plans = [state.plans[f"{i:02d}"] for i in range(layers)]
                    sig = state.sig
                elif refresh is not None:
                    plans = fresh() if refresh == 1 else jax.lax.cond(
                        it % refresh == 0, fresh, lambda: plans)
                cur = plans if refresh is not None else None
                gw, gi, go = jax.grad(loss, argnums=(0, 1, 2))(
                    ws, igs, ogs, cur)
                ws = [w - 1e-3 * d for w, d in zip(ws, gw)]
                # churn-then-freeze: big per-step perturbation of the
                # grouping matrices early (argmaxes flip), nothing late
                scale = jnp.where(it < churn_steps, 1.0, 0.0)
                kn = jax.random.fold_in(jax.random.PRNGKey(7), it)
                igs = [a - scale * (1e-1 * d + jax.random.normal(
                    jax.random.fold_in(kn, i), a.shape))
                    for i, (a, d) in enumerate(zip(igs, gi))]
                ogs = [a - scale * (1e-1 * d + jax.random.normal(
                    jax.random.fold_in(kn, 100 + i), a.shape))
                    for i, (a, d) in enumerate(zip(ogs, go))]
                return (ws, igs, ogs, plans, sig), ()
            carry, _ = jax.lax.scan(body, (ws, igs, ogs, plans, sig),
                                    jnp.arange(k_steps))
            return carry[0][0]
        return jax.jit(run)

    plans0 = [make_plan(ig, og, cfg.capacity_slack)
              for ig, og in zip(igs, ogs)]
    sig0 = encoder.plan_signature(gm_tree(igs, ogs))
    row(f"# amortization: {k_steps}-step scan, {layers}x({m}x{m}) G={g}, "
        f"batch {batch}, T={t_steps} fwd, grads+SGD each step; grouping "
        f"churns for {churn_steps} steps then freezes")
    row("variant", "per_step_us", "speedup_vs_per_call")
    variants = (("per_call", None), ("refresh_1", 1),
                ("refresh_4", 4), ("refresh_8", 8),
                ("on_change", "on_change"))
    from repro import kernels as kernels_mod
    with kernels_mod.use_reference_impl():
        best = timeit_interleaved({n: chunk(r) for n, r in variants},
                                  ws, igs, ogs, plans0, sig0, reps=24,
                                  stat="median")
    t_base = best["per_call"] / k_steps
    result = {}
    for name, _ in variants:
        t = best[name] / k_steps
        result[name] = {"per_step_s": t, "speedup": t_base / t}
        row(name, f"{t * 1e6:.0f}", f"{t_base / t:.2f}")
    # Fidelity-aware acceptance. On this trace the churn phase flips
    # argmaxes on consecutive steps, so the only fixed period whose
    # metadata keeps up with the update cadence (the GST condition the
    # refactor targets) is refresh_1 — every k>1 trains on stale plans
    # mid-churn. on_change must beat that tracking period while giving
    # the same exactness. Since the signature hashes placement ranks
    # (bitwise-exact freshness incl. slack>1 spill-order drift), its
    # per-step cost is ~half an encode, so in this encode-dominated
    # micro setting the coarse periods keep the edge their staleness
    # buys — on_change is the exactness frontier, refresh_k the
    # throughput frontier. We report both comparisons.
    best_fixed = max(result[n]["speedup"]
                     for n in ("refresh_1", "refresh_4", "refresh_8"))
    result["on_change_beats_tracking_fixed"] = \
        result["on_change"]["speedup"] >= result["refresh_1"]["speedup"]
    result["on_change_vs_best_fixed"] = \
        result["on_change"]["speedup"] / best_fixed
    row("# acceptance: refresh_every >= 4 must beat per-call make_plan;")
    row("# on_change must beat the churn-tracking fixed period "
        "(refresh_1) at equal exactness:",
        result["on_change_beats_tracking_fixed"])
    row("# informational — on_change/best_fixed (coarse periods buy their"
        " edge with churn-phase staleness the exact signature refuses):",
        f"{result['on_change_vs_best_fixed']:.2f}")
    return result


if __name__ == "__main__":
    main()
