"""Fig. 13 — speedup of sparse (grouped) over dense execution.

The paper's headline: 1.97–12.52× inference / 1.92–9.75× training speedup
from processing only unmasked weights (G = 2..16 ⇒ 50–93.75 % sparsity).

On this CPU host we measure the same quantity the paper measures — wall
time of the dense path vs the FLGW compact (grouped) path — on an
IC3Net-scale stack of FLGW layers (the paper's workload), plus the
FLOP-derived ideal speedup (= G, the paper's linear scaling) for the TPU
target where the MXU runs the G dense tiles at full utilization.

The decode column measures the serving-side amortization: the real LM
decode step against the PlanState cached beside the KV cache vs the same
step re-encoding every grouped projection per call (interleaved timing —
host-load drift hits both variants equally).

The d_ff-scale cell pits the fused consume path (compact weights cached
beside the plan, ``init_cache(..., params=...)``) against the pre-PR
baseline on the *same* real decode step: identical cached plans but
``compact=False``, so every grouped projection re-gathers W and x through
XLA per step (``grouped_matmul``) — exactly the path this repo shipped
before the fused kernel. The grouped item count M = 8192 (d_ff scale)
puts the projections beyond the old 4096-item encode cap that used to
force a lexsort fallback.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (row, save, timeit, timeit_interleaved,
                               write_bench_json)
from repro.core.flgw import FLGWConfig, init_grouping
from repro.core.grouped import grouped_apply

M = N = 1024       # layer size (IC3Net-class FC, scaled to be measurable)
B = 64             # batch
B_DEC = 4          # decode batch (few in-flight requests, one token each)
LAYERS = 4


def _stack(path: str, g: int):
    cfg = FLGWConfig(groups=g, path=path)
    key = jax.random.PRNGKey(0)
    ws, igs, ogs = [], [], []
    for i in range(LAYERS):
        k = jax.random.fold_in(key, i)
        ws.append(jax.random.normal(k, (M, N), jnp.float32))
        gm = init_grouping(jax.random.fold_in(k, 1), M, N, max(g, 2))
        igs.append(gm["ig"])
        ogs.append(gm["og"])

    def fwd(x):
        for w, ig, og in zip(ws, igs, ogs):
            if path == "dense" or g <= 1:
                x = jnp.tanh(x @ w)
            else:
                x = jnp.tanh(grouped_apply(x, w, ig, og, cfg))
        return x

    def train(x, y):
        def loss(ws_):
            h = x
            for w, ig, og in zip(ws_, igs, ogs):
                if path == "dense" or g <= 1:
                    h = jnp.tanh(h @ w)
                else:
                    h = jnp.tanh(grouped_apply(h, w, ig, og, cfg))
            return jnp.mean((h - y) ** 2)
        return jax.grad(loss)(ws)

    return jax.jit(fwd), jax.jit(train)


def _decode_pair(g: int):
    """The real serving decode step, twice: against the PlanState cached
    beside the KV cache (``transformer.init_cache(..., params=...)``) vs
    a bare cache, where every grouped projection falls back to per-call
    re-encoding inside the compiled step. One decode step re-encodes each
    FLGW layer (q/k/v/o + up/gate/down) on the bare path, so the gap is
    exactly the amortization the serving PlanState buys. Returns a
    zero-arg fn dict for ``timeit_interleaved``."""
    from repro.models import transformer
    from repro.models.config import ModelConfig
    from repro.serving import steps as serving_steps

    cfg = ModelConfig(
        name=f"fig13_decode_g{g}", family="dense", n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128, vocab=256,
        flgw_groups=g, flgw_path="grouped", flgw_targets=("mlp", "attn"),
        dtype=jnp.float32, remat=False)
    params, _ = transformer.lm_init(jax.random.PRNGKey(5), cfg)
    cache_cached = transformer.init_cache(cfg, B_DEC, 32, params=params)
    cache_bare = transformer.init_cache(cfg, B_DEC, 32)
    serve = jax.jit(serving_steps.make_decode_step(cfg))
    tok = jnp.zeros((B_DEC, 1), jnp.int32)
    return {"cached": lambda: serve(params, cache_cached, tok, tok),
            "percall": lambda: serve(params, cache_bare, tok, tok)}


DFF_M, DFF_G = 8192, 8    # grouped-projection item count, d_ff scale


def _dff_decode_pair():
    """The d_ff-scale serve step, twice: fused consume (compact weights
    cached beside the plan) vs the pre-PR XLA-gather path (same cached
    plans, ``compact=False`` — W and x re-gathered per step).

    The cell groups the attention projections at ``M = d_model = 8192``
    (a d_ff-scale item count, beyond the old 4096-item encode cap): the
    q/k/v shapes (8192 → 128) are the wide-contraction/narrow-output case
    where the per-step XLA gather-mask-transpose chain the fused prologue
    retires is largest relative to the matmul itself."""
    from repro.models import transformer
    from repro.models.config import ModelConfig
    from repro.serving import steps as serving_steps

    cfg = ModelConfig(
        name="fig13_dff", family="dense", n_layers=1, d_model=DFF_M,
        n_heads=2, n_kv_heads=2, head_dim=64, d_ff=512, vocab=256,
        flgw_groups=DFF_G, flgw_path="grouped", flgw_targets=("attn",),
        dtype=jnp.float32, remat=False)
    params, _ = transformer.lm_init(jax.random.PRNGKey(5), cfg)
    cache_fused = transformer.init_cache(cfg, B_DEC, 32, params=params)
    cache_gather = transformer.init_cache(cfg, B_DEC, 32, params=params,
                                          compact=False)
    serve = jax.jit(serving_steps.make_decode_step(cfg))
    tok = jnp.zeros((B_DEC, 1), jnp.int32)
    return {"fused": lambda: serve(params, cache_fused, tok, tok),
            "gather": lambda: serve(params, cache_gather, tok, tok)}


def main() -> dict:
    x = jax.random.normal(jax.random.PRNGKey(1), (B, M))
    y = jax.random.normal(jax.random.PRNGKey(2), (B, N))
    fwd_d, train_d = _stack("dense", 1)
    t_inf_dense = timeit(fwd_d, x)
    t_tr_dense = timeit(train_d, x, y)

    out = {"dense_inference_s": t_inf_dense,
           "dense_training_s": t_tr_dense, "cells": []}
    slack = FLGWConfig().capacity_slack
    row("# fig13_speedup: dense vs grouped,"
        f" {LAYERS}x({M}x{N}) layers, batch {B} (decode batch {B_DEC})")
    row("G", "sparsity_%", "cpu_inf_speedup", "cpu_train_speedup",
        "decode_plan_amortization", "tpu_flop_speedup(=G/slack^2)")
    for g in (2, 4, 8, 16):
        fwd_g, train_g = _stack("grouped", g)
        s_inf = t_inf_dense / timeit(fwd_g, x)
        s_tr = t_tr_dense / timeit(train_g, x, y)
        # Decode column: cached-plan decode vs per-call re-encoding,
        # measured round-robin so host-load drift hits both variants
        # equally (benchmarks/common.timeit_interleaved).
        t_dec = timeit_interleaved(_decode_pair(g), reps=16, stat="median")
        s_dec = t_dec["percall"] / t_dec["cached"]
        tpu = g / slack ** 2
        row(g, f"{100 * (1 - 1 / g):.1f}", f"{s_inf:.2f}", f"{s_tr:.2f}",
            f"{s_dec:.2f}", f"{tpu:.2f}")
        out["cells"].append({"G": g, "sparsity": 1 - 1 / g,
                             "inference_speedup": s_inf,
                             "training_speedup": s_tr,
                             "decode_cached_s": t_dec["cached"],
                             "decode_percall_s": t_dec["percall"],
                             "decode_plan_amortization": s_dec,
                             "tpu_flop_speedup": tpu, "ideal": g})
    amortized = [c["decode_plan_amortization"] > 1.0 for c in out["cells"]]
    out["decode_amortization_wins"] = sum(amortized)

    # d_ff-scale cell: fused consume vs the pre-PR XLA-gather serve step
    t_dff = timeit_interleaved(_dff_decode_pair(), reps=16, stat="median")
    dff = {"M": DFF_M, "G": DFF_G, "batch": B_DEC,
           "decode_fused_s": t_dff["fused"],
           "decode_gather_s": t_dff["gather"],
           "fused_speedup": t_dff["gather"] / t_dff["fused"]}
    out["dff_cell"] = dff
    row(f"# dff cell (M={DFF_M}, G={DFF_G}, grouped attn): fused"
        f" {t_dff['fused'] * 1e3:.1f}ms vs pre-PR gather"
        f" {t_dff['gather'] * 1e3:.1f}ms ->"
        f" {dff['fused_speedup']:.3f}x on the real decode step")
    row("# paper: 1.97-12.52x inference, 1.92-9.75x training (G=2..16).")
    row("# decode_plan_amortization: grouped decode against the cached")
    row("# PlanState (beside the KV cache) vs plan=None per-call re-encode"
        f" — beats per-call in {sum(amortized)}/{len(amortized)} cells.")
    row("# The TPU column is the SPMD-verified compact-path compute ratio")
    row("# (dry-run measured 0.40x dense at G=4 = slack^2/G; see §Perf A6).")
    save("fig13_speedup", out)
    write_bench_json("fig13_speedup", {
        "config": {"layers": LAYERS, "m": M, "n": N, "batch": B,
                   "decode_batch": B_DEC, "capacity_slack": slack,
                   "dff_m": DFF_M, "dff_g": DFF_G},
        "results": {"dense_inference_s": t_inf_dense,
                    "dense_training_s": t_tr_dense, "cells": out["cells"],
                    "dff_cell": dff},
        "acceptance": {
            "speedup_grows_with_g":
                out["cells"][-1]["inference_speedup"]
                > out["cells"][0]["inference_speedup"],
            "decode_amortization_wins_majority":
                out["decode_amortization_wins"] * 2 > len(out["cells"]),
            "dff_fused_beats_pre_pr_gather": dff["fused_speedup"] > 1.0,
        }})
    return out


if __name__ == "__main__":
    main()
