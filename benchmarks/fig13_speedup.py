"""Fig. 13 — speedup of sparse (grouped) over dense execution.

The paper's headline: 1.97–12.52× inference / 1.92–9.75× training speedup
from processing only unmasked weights (G = 2..16 ⇒ 50–93.75 % sparsity).

On this CPU host we measure the same quantity the paper measures — wall
time of the dense path vs the FLGW compact (grouped) path — on an
IC3Net-scale stack of FLGW layers (the paper's workload), plus the
FLOP-derived ideal speedup (= G, the paper's linear scaling) for the TPU
target where the MXU runs the G dense tiles at full utilization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, save, timeit
from repro.core.flgw import FLGWConfig, init_grouping
from repro.core.grouped import grouped_apply

M = N = 1024       # layer size (IC3Net-class FC, scaled to be measurable)
B = 64             # batch
LAYERS = 4


def _stack(path: str, g: int):
    cfg = FLGWConfig(groups=g, path=path)
    key = jax.random.PRNGKey(0)
    ws, igs, ogs = [], [], []
    for i in range(LAYERS):
        k = jax.random.fold_in(key, i)
        ws.append(jax.random.normal(k, (M, N), jnp.float32))
        gm = init_grouping(jax.random.fold_in(k, 1), M, N, max(g, 2))
        igs.append(gm["ig"])
        ogs.append(gm["og"])

    def fwd(x):
        for w, ig, og in zip(ws, igs, ogs):
            if path == "dense" or g <= 1:
                x = jnp.tanh(x @ w)
            else:
                x = jnp.tanh(grouped_apply(x, w, ig, og, cfg))
        return x

    def train(x, y):
        def loss(ws_):
            h = x
            for w, ig, og in zip(ws_, igs, ogs):
                if path == "dense" or g <= 1:
                    h = jnp.tanh(h @ w)
                else:
                    h = jnp.tanh(grouped_apply(h, w, ig, og, cfg))
            return jnp.mean((h - y) ** 2)
        return jax.grad(loss)(ws)

    return jax.jit(fwd), jax.jit(train)


def main() -> dict:
    x = jax.random.normal(jax.random.PRNGKey(1), (B, M))
    y = jax.random.normal(jax.random.PRNGKey(2), (B, N))
    fwd_d, train_d = _stack("dense", 1)
    t_inf_dense = timeit(fwd_d, x)
    t_tr_dense = timeit(train_d, x, y)

    out = {"dense_inference_s": t_inf_dense,
           "dense_training_s": t_tr_dense, "cells": []}
    slack = FLGWConfig().capacity_slack
    row("# fig13_speedup: dense vs grouped,"
        f" {LAYERS}x({M}x{N}) layers, batch {B}")
    row("G", "sparsity_%", "cpu_inf_speedup", "cpu_train_speedup",
        "tpu_flop_speedup(=G/slack^2)")
    for g in (2, 4, 8, 16):
        fwd_g, train_g = _stack("grouped", g)
        s_inf = t_inf_dense / timeit(fwd_g, x)
        s_tr = t_tr_dense / timeit(train_g, x, y)
        tpu = g / slack ** 2
        row(g, f"{100 * (1 - 1 / g):.1f}", f"{s_inf:.2f}", f"{s_tr:.2f}",
            f"{tpu:.2f}")
        out["cells"].append({"G": g, "sparsity": 1 - 1 / g,
                             "inference_speedup": s_inf,
                             "training_speedup": s_tr,
                             "tpu_flop_speedup": tpu, "ideal": g})
    row("# paper: 1.97-12.52x inference, 1.92-9.75x training (G=2..16).")
    row("# The TPU column is the SPMD-verified compact-path compute ratio")
    row("# (dry-run measured 0.40x dense at G=4 = slack^2/G; see §Perf A6).")
    save("fig13_speedup", out)
    return out


if __name__ == "__main__":
    main()
