"""Shared benchmark utilities: timing, result persistence, CSV output."""
from __future__ import annotations

import json
import pathlib
import time

import jax

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call of a jit'd fn (blocks on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def timeit_interleaved(fns: dict, *args, reps: int = 12,
                       stat: str = "min") -> dict:
    """Wall seconds per call for several jit'd fns measured round-robin.

    Interleaving makes slow drifts in machine load hit every variant
    equally. ``stat="min"`` is robust to isolated load spikes;
    ``stat="median"`` is the better estimator when the host baseline
    wanders (min draws are heavy-tailed-lucky, so small structural gaps
    between variants flap under min). Use this when *comparing* variants
    on a shared host.
    """
    for fn in fns.values():
        jax.block_until_ready(fn(*args))        # compile + warm
    times = {name: [] for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            times[name].append(time.perf_counter() - t0)
    if stat == "min":
        return {name: min(ts) for name, ts in times.items()}
    return {name: sorted(ts)[len(ts) // 2] for name, ts in times.items()}


def save(name: str, payload) -> pathlib.Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{name}.json"
    path.write_text(json.dumps(payload, indent=1, default=float))
    return path


def row(*cells):
    print(",".join(str(c) for c in cells), flush=True)
