"""Shared benchmark utilities: timing, result persistence, CSV output."""
from __future__ import annotations

import json
import pathlib
import time

import jax

RESULTS = pathlib.Path(__file__).resolve().parent / "results"
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call of a jit'd fn (blocks on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def timeit_interleaved(fns: dict, *args, reps: int = 12,
                       stat: str = "min") -> dict:
    """Wall seconds per call for several jit'd fns measured round-robin.

    Interleaving makes slow drifts in machine load hit every variant
    equally. ``stat="min"`` is robust to isolated load spikes;
    ``stat="median"`` is the better estimator when the host baseline
    wanders (min draws are heavy-tailed-lucky, so small structural gaps
    between variants flap under min). Use this when *comparing* variants
    on a shared host.
    """
    for fn in fns.values():
        jax.block_until_ready(fn(*args))        # compile + warm
    times = {name: [] for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            times[name].append(time.perf_counter() - t0)
    if stat == "min":
        return {name: min(ts) for name, ts in times.items()}
    return {name: sorted(ts)[len(ts) // 2] for name, ts in times.items()}


def save(name: str, payload) -> pathlib.Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{name}.json"
    path.write_text(json.dumps(payload, indent=1, default=float))
    return path


def write_bench_json(name: str, payload: dict, *,
                     out_dir: pathlib.Path = REPO_ROOT) -> pathlib.Path:
    """Persist a benchmark's committed artifact as ``BENCH_<name>.json``.

    Unlike :func:`save` (scratch copies under the gitignored
    ``benchmarks/results/``), these land at the repo root so runs can be
    committed and diffed. Every figure script routes its canonical output
    through here with the same shape::

        {"bench": <name>, "config": {...knobs...},
         "results": {...medians...}, "acceptance": {flag: bool, ...}}

    ``config`` / ``results`` / ``acceptance`` are required so artifacts
    stay machine-comparable across PRs; extra top-level keys pass
    through. Timings inside ``results`` should be medians (``timeit`` or
    ``timeit_interleaved(..., stat="median")``) — committed numbers need
    the estimator that's robust on a wandering shared host.
    """
    missing = [k for k in ("config", "results", "acceptance")
               if k not in payload]
    if missing:
        raise ValueError(f"bench payload for {name!r} missing {missing}")
    bad = [k for k, v in payload["acceptance"].items()
           if not isinstance(v, bool)]
    if bad:
        raise ValueError(f"acceptance flags must be plain bools: {bad}")
    doc = {"bench": name, **payload}
    path = pathlib.Path(out_dir) / f"BENCH_{name}.json"
    path.write_text(json.dumps(doc, indent=1, default=float) + "\n")
    return path


def row(*cells):
    print(",".join(str(c) for c in cells), flush=True)
