"""Shared benchmark utilities: timing, result persistence, CSV output."""
from __future__ import annotations

import json
import pathlib
import time

import jax

RESULTS = pathlib.Path(__file__).resolve().parent / "results"


def timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call of a jit'd fn (blocks on the result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def timeit_interleaved(fns: dict, *args, reps: int = 12) -> dict:
    """Min wall seconds per call for several jit'd fns measured round-robin.

    Interleaving makes slow drifts in machine load hit every variant
    equally, and min (unlike median) is robust to load spikes — use this
    when *comparing* variants on a shared host.
    """
    for fn in fns.values():
        jax.block_until_ready(fn(*args))        # compile + warm
    best = {name: float("inf") for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[name] = min(best[name], time.perf_counter() - t0)
    return best


def save(name: str, payload) -> pathlib.Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{name}.json"
    path.write_text(json.dumps(payload, indent=1, default=float))
    return path


def row(*cells):
    print(",".join(str(c) for c in cells), flush=True)
