"""fig14_serving: continuous batching vs lockstep on the serving tier.

The paper's accelerator keeps the sparse datapath busy by overlapping
plan (OSEL) generation with compute; the serving-tier analogue is
keeping the decode batch full. This benchmark drives one plan-aware
:class:`repro.serving.ServeSession` (tiny grouped model, the fig13
``_decode_pair`` config) through the same open-loop Geometric request
stream under both admission disciplines of ``repro.serving.Engine``:

* ``lockstep``   — static batching: a batch admits only into an all-free
  engine and runs to its slowest member;
* ``continuous`` — slot-based continuous batching: a finished request's
  slot takes the next prefill while its neighbours keep decoding.

Both run the *same* jitted unified step over the *same* per-slot cache
at the same capacity, so the gap isolates the scheduling discipline:
continuous needs ~total_work/capacity steps where lockstep needs
sum-of-batch-maxima, and with one compiled program per step, tokens/s
follows the step count. Latency is wall time from a request's arrival
tick to its completion. The plan cache is cleared first so the run also
certifies the one-encode-per-params-version invariant end to end.

  PYTHONPATH=src python benchmarks/fig14_serving.py [--check] [--no-write]

``--check`` exits nonzero unless every acceptance flag holds (CI);
``--no-write`` keeps CI smoke runs from overwriting the committed
``BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import row, save, write_bench_json
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serving import Engine, ServeSession, plan_cache, synthetic_requests
from repro.serving.stream import max_seq_for

GROUPS = 4


def _config() -> ModelConfig:
    return ModelConfig(
        name="fig14_serving", family="dense", n_layers=2, d_model=64,
        n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128, vocab=256,
        flgw_groups=GROUPS, flgw_path="grouped",
        flgw_targets=("mlp", "attn"), dtype=jnp.float32, remat=False)


def run(n_requests: int = 24, capacity: int = 4, p_arrive: float = 0.5,
        seed: int = 0, reps: int = 5) -> dict:
    cfg = _config()
    params, _ = transformer.lm_init(jax.random.PRNGKey(3), cfg)
    plan_cache.clear()
    session = ServeSession(cfg, params, plan_policy="certify")

    requests = synthetic_requests(seed, n_requests, vocab=cfg.vocab,
                                  p_arrive=p_arrive, prompt_len=(4, 12),
                                  gen_len=(4, 16))
    max_seq = max_seq_for(requests)
    engines = {mode: Engine(session, capacity=capacity, max_seq=max_seq,
                            admission=mode)
               for mode in ("continuous", "lockstep")}

    # Warm the single compiled step (shared by both modes) plus the
    # reset_slots jit so the timed reps measure scheduling, not XLA.
    warm = synthetic_requests(seed + 1, 2, vocab=cfg.vocab,
                              prompt_len=(4, 12), gen_len=(4, 16))
    for eng in engines.values():
        eng.run(warm)

    # Interleave reps so host-load drift hits both disciplines equally,
    # then report each mode's median-throughput rep (medians, per
    # benchmarks/common house rules for committed numbers).
    reports = {mode: [] for mode in engines}
    for _ in range(reps):
        for mode, eng in engines.items():
            reports[mode].append(eng.run(requests))
    med = {mode: sorted(rs, key=lambda r: r.tokens_per_s)[len(rs) // 2]
           for mode, rs in reports.items()}

    pc = plan_cache.stats()
    cont, lock = med["continuous"], med["lockstep"]
    out = {
        "config": {"model": cfg.name, "groups": GROUPS,
                   "targets": list(cfg.flgw_targets),
                   "requests": n_requests, "capacity": capacity,
                   "p_arrive": p_arrive, "seed": seed, "reps": reps,
                   "max_seq": max_seq, "plan_policy": "certify"},
        "results": {mode: med[mode].summary() for mode in med},
        "acceptance": {
            "continuous_beats_lockstep_tokens_per_s":
                cont.tokens_per_s > lock.tokens_per_s,
            "continuous_fewer_steps": cont.steps < lock.steps,
            "all_requests_completed": all(
                len(r.records) == n_requests
                and all(rec.completed >= 0 for rec in r.records)
                for rs in reports.values() for r in rs),
            "single_plan_encode": pc["encodes"] == 1,
        },
    }
    out["results"]["plan_cache"] = dict(pc)
    out["results"]["speedup_tokens_per_s"] = (
        cont.tokens_per_s / lock.tokens_per_s)

    row("# fig14_serving: continuous vs lockstep admission, "
        f"{n_requests} requests, capacity {capacity}, "
        f"p_arrive {p_arrive}, median of {reps} interleaved reps")
    row("mode", "steps", "tok_per_s", "slot_util_%", "p50_ms", "p99_ms")
    for mode in ("lockstep", "continuous"):
        s = med[mode].summary()
        row(mode, s["steps"], f"{s['tokens_per_s']:.1f}",
            f"{100 * s['slot_utilization']:.0f}",
            f"{1e3 * s['p50_s']:.1f}", f"{1e3 * s['p99_s']:.1f}")
    row(f"# continuous/lockstep tokens-per-s: "
        f"{out['results']['speedup_tokens_per_s']:.2f}x; plan encodes "
        f"across {2 * reps + 2} engine runs: {pc['encodes']}")
    for flag, ok in out["acceptance"].items():
        row(f"# acceptance {flag}:", ok)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--capacity", type=int, default=4)
    ap.add_argument("--p-arrive", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless every acceptance flag holds")
    ap.add_argument("--no-write", action="store_true",
                    help="skip BENCH_serving.json (CI smoke runs must not "
                         "overwrite the committed artifact)")
    args = ap.parse_args(argv)

    out = run(n_requests=args.requests, capacity=args.capacity,
              p_arrive=args.p_arrive, seed=args.seed, reps=args.reps)
    save("fig14_serving", out)
    if not args.no_write:
        write_bench_json("serving", out)
    if args.check and not all(out["acceptance"].values()):
        row("# CHECK FAILED:", {k: v for k, v in out["acceptance"].items()
                                if not v})
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
