"""Fig. 9 / Fig. 4a — MARL training accuracy vs sparsity (group number).

Trains IC3Net with FLGW at G ∈ {1, 2, 4, 8} and reports the average
success rate, reproducing the paper's accuracy-vs-sparsity curve shape:
accuracy holds near the dense baseline through G=4 (75 % sparsity) and
degrades gracefully beyond. Any environment registered in
``repro.marl.envs`` can be swept (``--envs predator_prey traffic_junction
spread``); the paper's own condition is Predator-Prey.

The paper runs 2000 iterations x batch 32 on an FPGA; the CPU-budget
default here is --iters 800 x batch 16 on a smaller grid, which reproduces
the claim (accuracy ~= dense through G=4; G=8 degrades at A=4). Pass
--iters 2000 --size 5 --agents 8 for the full published setup.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import row, save, write_bench_json
from repro.marl import envs as envs_mod
from repro.marl import ic3net
from repro.marl import train as train_mod


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=800)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--size", type=int, default=4)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--groups", type=int, nargs="+", default=[1, 2, 4, 8])
    ap.add_argument("--envs", nargs="+", default=["predator_prey"],
                    choices=envs_mod.names())
    ap.add_argument("--no-write", action="store_true",
                    help="skip refreshing the committed BENCH json")
    args = ap.parse_args(argv)

    tcfg = train_mod.TrainConfig(batch=args.batch)
    out = {"iters": args.iters, "agents": args.agents, "cells": []}
    row(f"# fig9_accuracy: IC3Net, A={args.agents}, {args.iters} iters, "
        f"envs={args.envs}")
    row("env", "G", "sparsity_%", "success_final_%", "success_mean_%")
    for env_name in args.envs:
        env, ecfg = envs_mod.make(
            env_name, n_agents=args.agents, size=args.size,
            max_steps=3 * args.size)
        for g in args.groups:
            cfg = ic3net.IC3NetConfig(hidden=128, flgw_groups=g,
                                      flgw_path="masked")
            _, hist = train_mod.train(cfg, ecfg, tcfg,
                                      iterations=args.iters, seed=0,
                                      env=env)
            succ = np.array([h["success"] for h in hist])
            tail = float(succ[-max(1, args.iters // 10):].mean() * 100)
            mean = float(succ.mean() * 100)
            row(env_name, g, f"{100 * (1 - 1 / max(g, 1)):.1f}",
                f"{tail:.1f}", f"{mean:.1f}")
            out["cells"].append({"env": env_name, "G": g,
                                 "sparsity": 1 - 1 / max(g, 1),
                                 "final_success_pct": tail,
                                 "mean_success_pct": mean})
    row("# paper: accuracy ~= dense through G=4 (75% sparsity); "
        "G=8 holds with >=8 agents")
    save("fig9_accuracy", out)
    if not args.no_write:
        # the paper's claim, as flags over whatever grid actually ran:
        # grouping through G=4 stays within 15pp of the dense (G=1) point
        dense = {c["env"]: c["final_success_pct"] for c in out["cells"]
                 if c["G"] == 1}
        mid = [c for c in out["cells"] if c["env"] in dense
               and 1 < c["G"] <= 4]
        write_bench_json("fig9_accuracy", {
            "config": {"iters": args.iters, "agents": args.agents,
                       "size": args.size, "batch": args.batch,
                       "groups": args.groups, "envs": args.envs},
            "results": {"cells": out["cells"]},
            "acceptance": {
                "all_points_trained":
                    all(np.isfinite(c["final_success_pct"])
                        for c in out["cells"]),
                "g_le_4_within_15pp_of_dense":
                    all(c["final_success_pct"]
                        >= dense[c["env"]] - 15.0 for c in mid),
            }})
    return out


if __name__ == "__main__":
    main()
