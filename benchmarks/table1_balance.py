"""Table I — workload deviation of allocation schemes.

Tracks the deviation (max |core_nnz − ideal|) of threshold-based (paper
baseline), row-based (paper scheme) and capacity-balanced (our TPU
adaptation) allocation over simulated training: the mask is re-derived from
freshly trained-looking grouping matrices each iteration, C=3 cores (the
paper's config), G ∈ {2, 4, 8, 16}, layer 128×512.

Paper: row-based achieves 44.9/70.1/8.7/35.9 % lower deviation than
threshold at G=2/4/8/16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, save
from repro.core import flgw
from repro.core.grouped import make_plan
from repro.core.load_balance import (balanced_allocate, deviation,
                                     row_allocate, threshold_allocate)

M, N, CORES, ITERS = 128, 512, 3, 50


def main() -> dict:
    out = {"cores": CORES, "layer": [M, N], "cells": []}
    row("# table1_balance: max deviation from ideal workload, "
        f"C={CORES}, {ITERS} iterations")
    row("G", "threshold(paper-baseline)", "row(paper)",
        "balanced(ours)", "row_vs_thr_%less", "bal_vs_thr_%less")
    key = jax.random.PRNGKey(0)
    for g in (2, 4, 8, 16):
        d_thr, d_row, d_bal = [], [], []
        for it in range(ITERS):
            k = jax.random.fold_in(key, g * 1000 + it)
            ig = jax.random.normal(k, (M, g))
            og = jax.random.normal(jax.random.fold_in(k, 1), (g, N))
            ig_idx, og_idx = flgw.grouping_indices(ig, og)
            mask = np.asarray(flgw.mask_from_indices(ig_idx, og_idx))
            d_thr.append(deviation(threshold_allocate(mask, CORES)))
            d_row.append(deviation(row_allocate(mask, CORES)))
            plan = make_plan(ig, og)
            d_bal.append(deviation(balanced_allocate(
                np.asarray(plan.row_group), np.asarray(plan.col_group),
                CORES, g)))
        thr, rw, bal = map(lambda v: float(np.max(v)), (d_thr, d_row, d_bal))
        less_row = 100.0 * (1 - rw / thr) if thr else 0.0
        less_bal = 100.0 * (1 - bal / thr) if thr else 0.0
        row(g, f"{thr:.2f}", f"{rw:.2f}", f"{bal:.2f}",
            f"{less_row:.1f}", f"{less_bal:.1f}")
        out["cells"].append({"G": g, "threshold": thr, "row": rw,
                             "balanced": bal, "row_vs_thr_pct": less_row,
                             "bal_vs_thr_pct": less_bal})
    row("# paper Table I row-vs-threshold: 44.9/70.1/8.7/35.9 % less")
    save("table1_balance", out)
    return out


if __name__ == "__main__":
    main()
