"""Fig. 11 — accelerator throughput / energy-efficiency model.

The paper's measurement is FPGA wall-clock GFLOPS under three sweeps
(agents, batch, group number). Without the FPGA (or a TPU), we reproduce
the *model* behind the figure, grounded in measured quantities:

* dense-equivalent FLOPs of one IC3Net step (A agents, batch B) computed
  from the network dims — the same accounting the paper uses;
* the measured sparse-over-dense wall-time speedup of our grouped path
  (fig13 measurement, this host) as the utilization proxy;
* the target's peak (TPU v5e 197 TFLOP/s bf16, vs the paper's 3-core
  264-wide FP16 FPGA at 175 MHz = 277 GFLOPS peak).

Reported: effective GFLOPS for the FPGA-model (paper's 257.4 dense,
3629.5 @G=16 claims as anchors) and the TPU-scaled equivalent.

``--real`` additionally sweeps *measured* runs of the MARL engine: the
training loop now accumulates per-iteration throughput (steps/s, realised
mask sparsity, estimated sparse GFLOPS) from inside the on-device scan, so
the paper's three sweeps (agents / batch / group number) can be driven by
real `train()` calls on this host instead of synthetic shapes.
"""
from __future__ import annotations

from benchmarks.common import row, save, write_bench_json

# IC3Net dims (hidden 128), paper setup
H = 128
FPGA_PEAK = 3 * 264 * 2 * 175e6 / 1e9   # 3 cores x 264 MACs x 2 flops @175MHz
FPGA_UTIL_DENSE = 0.8696                # paper: dense MAC utilization
FPGA_UTIL_SPARSE = 0.9689               # paper: sparse MAC utilization
FPGA_POWER_W = 36.3                     # paper average


def ic3net_flops_per_step(agents: int, obs_dim: int = 64) -> float:
    """Dense-equivalent FLOPs of one forward+comm step for all agents."""
    per_agent = 2 * (obs_dim * H          # encoder
                     + H * 4 * H * 2      # LSTM x/h gates
                     + H * H              # comm projection
                     + H * 5 + H + H * 2)  # heads
    return agents * per_agent


def main(write: bool = True) -> dict:
    out = {"fpga_peak_gflops": FPGA_PEAK, "cells": []}
    row("# fig11_throughput: modelled accelerator GFLOPS "
        f"(FPGA peak {FPGA_PEAK:.1f} GFLOPS)")
    row("sweep", "value", "dense_equiv_gflops", "paper_anchor")

    # Sweep 1+2 (agents / batch): dense throughput is flat — utilization
    # is fixed; effective GFLOPS = peak x dense utilization.
    dense_eff = FPGA_PEAK * FPGA_UTIL_DENSE
    for a in (3, 6, 10):
        row("agents", a, f"{dense_eff:.1f}", "257.4 (flat)")
        out["cells"].append({"sweep": "agents", "value": a,
                             "gflops": dense_eff})
    for b in (1, 8, 32):
        row("batch", b, f"{dense_eff:.1f}", "257.4 (flat)")
        out["cells"].append({"sweep": "batch", "value": b,
                             "gflops": dense_eff})

    # Sweep 3 (group number): dense-equivalent GFLOPS scales ~linearly
    # with G (compute only non-zeros, count dense FLOPs) — paper Fig 11.
    for g in (1, 2, 4, 8, 16):
        eff = FPGA_PEAK * (FPGA_UTIL_DENSE if g == 1 else FPGA_UTIL_SPARSE)
        dense_equiv = eff * g
        anchor = {1: "257.4", 16: "3629.5"}.get(g, "-")
        row("groups", g, f"{dense_equiv:.1f}", anchor)
        out["cells"].append({"sweep": "groups", "value": g,
                             "gflops": dense_equiv,
                             "gflops_per_w": dense_equiv / FPGA_POWER_W})
    row("# paper: 257.40-3629.48 GFLOPS, 7.10-100.12 GFLOPS/W")
    out["model_check"] = {
        "dense_gflops": dense_eff,
        "paper_dense_gflops": 257.4,
        "g16_gflops": FPGA_PEAK * FPGA_UTIL_SPARSE * 16,
        "paper_g16_gflops": 3629.48,
    }
    save("fig11_throughput", out)
    if write:
        write_bench_json("fig11_throughput", _model_payload(out))
    return out


def _model_payload(out: dict) -> dict:
    """The accelerator-model section of the committed fig11 artifact."""
    mc = out["model_check"]
    return {
        "config": {"fpga_peak_gflops": FPGA_PEAK,
                   "util_dense": FPGA_UTIL_DENSE,
                   "util_sparse": FPGA_UTIL_SPARSE,
                   "power_w": FPGA_POWER_W},
        "results": {"model_check": mc, "cells": out["cells"]},
        "acceptance": {
            # the utilization model lands within 10% of the paper's
            # measured dense point...
            "dense_within_10pct_of_paper":
                abs(mc["dense_gflops"] - mc["paper_dense_gflops"])
                / mc["paper_dense_gflops"] < 0.10,
            # ...and its idealized linear-in-G sparse scaling upper-
            # bounds the paper's measured G=16 point, as it must
            "g16_upper_bounds_paper_anchor":
                mc["g16_gflops"] >= mc["paper_g16_gflops"],
        }}


def async_sweep(updates: int = 16, hidden: int = 32, batch: int = 8,
                agents: int = 3, cadences: tuple = (1, 2, 4, 8),
                check: bool = False, write: bool = True) -> dict:
    """Actor/learner overlap vs the synchronous scan, same device count.

    The decoupling lever fig11's on-chip dataflow models: the synchronous
    scan pays a full forward+backward per rollout window, so its env-step
    rate is pinned to the learner's clock. The async pipeline amortizes
    one learner update over ``cadence`` actor windows (forward-only
    rollouts against the published snapshot), so generated env-steps/s
    grows with cadence while updates/s falls — the paper's
    throughput-vs-staleness trade, measured. Every cell runs V-trace
    (the correction that makes the staleness sound) after a short warmup
    run so jit compiles are off the clock; acceptance is the best async
    cell beating sync on env-steps/s at equal device count.

    Writes the COMBINED committed artifact (accelerator model + this
    sweep) so ``BENCH_fig11_throughput.json`` keeps one schema.
    """
    import jax

    from repro.marl import async_train as async_mod
    from repro.marl import envs, ic3net
    from repro.marl import train as train_mod

    cfg = ic3net.IC3NetConfig(hidden=hidden)
    env, ecfg = envs.make("predator_prey", n_agents=agents)
    tcfg = train_mod.TrainConfig(batch=batch)

    row(f"# fig11 --async: sync scan vs actor/learner overlap "
        f"(hidden={hidden}, batch={batch}, A={agents}, {updates} "
        f"updates/point, {len(jax.devices())} device(s))")
    row("variant", "cadence", "env_steps_per_s", "updates_per_s",
        "max_staleness")

    # sync baseline: warmup run compiles the scan chunk (its window length
    # n is a static arg, so the warmup must use the measured length), the
    # measured run reuses the compile cache
    train_mod.train(cfg, ecfg, tcfg, iterations=updates, seed=0, env=env)
    _, hist = train_mod.train(cfg, ecfg, tcfg, iterations=updates, seed=0,
                              env=env)
    sync = {"env_steps_per_s": hist[-1]["env_steps_per_s"],
            "updates_per_s": hist[-1]["steps_per_s"]}
    row("sync", "-", f"{sync['env_steps_per_s']:.0f}",
        f"{sync['updates_per_s']:.2f}", 0)

    cells = []
    for cadence in cadences:
        acfg = async_mod.AsyncConfig(
            capacity=max(4, cadence), actors=cadence, correction="vtrace",
            publish_every=1, max_staleness=2 * cadence + 2)
        async_mod.async_train(cfg, ecfg, tcfg, acfg=acfg, updates=2,
                              seed=0, env=env)              # warmup
        _, hist = async_mod.async_train(cfg, ecfg, tcfg, acfg=acfg,
                                        updates=updates, seed=0, env=env)
        cell = {"cadence": cadence,
                "env_steps_per_s": hist[-1]["env_steps_per_s"],
                "updates_per_s": hist[-1]["updates_per_s"],
                "max_staleness": max(h["staleness"] for h in hist)}
        row("async", cadence, f"{cell['env_steps_per_s']:.0f}",
            f"{cell['updates_per_s']:.2f}",
            f"{cell['max_staleness']:.0f}")
        cells.append(cell)

    best = max(cells, key=lambda c: c["env_steps_per_s"])
    out = {"sync": sync, "async_cells": cells, "best_cadence":
           best["cadence"]}
    row(f"# best async cadence {best['cadence']}: "
        f"{best['env_steps_per_s']:.0f} env-steps/s vs sync "
        f"{sync['env_steps_per_s']:.0f}")
    save("fig11_throughput_async", out)

    payload = _model_payload(main(write=False))
    payload["config"]["async"] = {
        "updates": updates, "hidden": hidden, "batch": batch,
        "agents": agents, "cadences": list(cadences),
        "correction": "vtrace", "devices": len(jax.devices())}
    payload["results"]["async_sweep"] = out
    payload["acceptance"]["async_env_steps_ge_sync"] = bool(
        best["env_steps_per_s"] >= sync["env_steps_per_s"])
    if write:
        write_bench_json("fig11_throughput", payload)
    if check:
        bad = [k for k, v in payload["acceptance"].items() if not v]
        if bad:
            raise SystemExit(f"fig11 acceptance failed: {bad}")
        row("# fig11 --check: all acceptance flags hold")
    return out


def real_sweep(iterations: int = 24, hidden: int = 64,
               mesh: tuple | None = None) -> dict:
    """Paper Fig. 11 sweeps measured on real ``train()`` runs.

    Each point runs the on-device scan (grouped path where G > 1, plan
    refresh every 4 iterations) and reads the throughput metrics the loop
    accumulates; the first half of each history (compile-heavy) is
    discarded. ``mesh=(env, agent)`` drives every point through the
    ``jax.sharding`` mesh path instead of the single-device scan (the
    batch stays the global batch — sharded, not multiplied).
    """
    from repro.core.schedule import SparsitySchedule
    from repro.marl import envs, ic3net
    from repro.marl import train as train_mod

    def measure(agents: int, batch: int, groups: int) -> dict:
        cfg = ic3net.IC3NetConfig(
            hidden=hidden, flgw_groups=groups,
            flgw_path="grouped" if groups > 1 else "masked")
        env, ecfg = envs.make("predator_prey", n_agents=agents)
        sched = (SparsitySchedule(groups=groups, refresh_every=4)
                 if groups > 1 else None)
        _, hist = train_mod.train(cfg, ecfg, train_mod.TrainConfig(
            batch=batch, mesh=mesh), iterations=iterations, seed=0, env=env,
            schedule=sched, log_every=max(2, iterations // 4))
        tail = hist[len(hist) // 2:]
        mean = lambda key: sum(h[key] for h in tail) / len(tail)
        return {"steps_per_s": mean("steps_per_s"),
                "env_steps_per_s": mean("env_steps_per_s"),
                "sparse_gflops": mean("sparse_gflops"),
                "mask_sparsity": mean("mask_sparsity")}

    out = {"cells": [], "mesh": list(mesh) if mesh else None}
    row("# fig11 --real: measured engine throughput (this host, "
        f"hidden={hidden}, {iterations} iters/point"
        + (f", mesh {mesh[0]}x{mesh[1]}" if mesh else "") + ")")
    row("sweep", "value", "steps_per_s", "env_steps_per_s",
        "est_sparse_gflops", "mask_sparsity")
    sweeps = ([("agents", a, dict(agents=a, batch=8, groups=4))
               for a in (3, 6, 10)]
              + [("batch", b, dict(agents=3, batch=b, groups=4))
                 for b in (1, 8, 32)]
              + [("groups", g, dict(agents=3, batch=8, groups=g))
                 for g in (1, 4, 16)])
    for sweep, value, kw in sweeps:
        cell = measure(**kw)
        row(sweep, value, f"{cell['steps_per_s']:.2f}",
            f"{cell['env_steps_per_s']:.0f}",
            f"{cell['sparse_gflops']:.3f}", f"{cell['mask_sparsity']:.3f}")
        out["cells"].append({"sweep": sweep, "value": value, **cell})
    save("fig11_throughput_real", out)
    write_bench_json("fig11_throughput_real", {
        "config": {"iterations": iterations, "hidden": hidden,
                   "mesh": list(mesh) if mesh else None},
        "results": {"cells": out["cells"]},
        "acceptance": {
            "all_points_trained":
                all(c["steps_per_s"] > 0 for c in out["cells"]),
            "grouped_sparsity_tracks_g":
                all(c["mask_sparsity"] > 0.5 for c in out["cells"]
                    if c["sweep"] == "groups" and c["value"] >= 4),
        }})
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--real", action="store_true",
                    help="sweep measured train() runs instead of the "
                         "accelerator model")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="measure the actor/learner overlap vs the sync "
                         "scan and fold it into the committed artifact")
    ap.add_argument("--iterations", type=int, default=24)
    ap.add_argument("--updates", type=int, default=16,
                    help="learner updates per --async cell")
    ap.add_argument("--hidden", type=int, default=None,
                    help="IC3Net hidden width (default: 64 for --real, "
                         "32 for --async)")
    ap.add_argument("--batch", type=int, default=8,
                    help="env batch of the --async sweep")
    ap.add_argument("--mesh", default=None,
                    help="ENV,AGENT shard counts: run the --real sweep on "
                         "the jax.sharding mesh path (e.g. 2,2)")
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless every acceptance flag holds "
                         "(with --async)")
    ap.add_argument("--no-write", action="store_true",
                    help="skip refreshing the committed BENCH json")
    args = ap.parse_args()
    mesh = None
    if args.mesh:
        if not args.real:
            ap.error("--mesh only affects measured runs; pass --real")
        from repro.launch.mesh import parse_marl_mesh
        try:
            mesh = parse_marl_mesh(args.mesh)
        except ValueError as e:
            ap.error(str(e))
    if args.check and not args.async_:
        ap.error("--check gates the --async acceptance flags; pass --async")
    if args.real:
        real_sweep(iterations=args.iterations, hidden=args.hidden or 64,
                   mesh=mesh)
    elif args.async_:
        async_sweep(updates=args.updates, hidden=args.hidden or 32,
                    batch=args.batch, check=args.check,
                    write=not args.no_write)
    else:
        main(write=not args.no_write)
