"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables.

``--vmem`` appends the per-kernel VMEM working-set table sourced from
the static auditor (``repro.analysis.kernel_audit``) instead of
hand-maintained docstring constants; ``--write-bench`` commits it as
``BENCH_kernel_vmem.json``. The ``--vmem`` path is jax-free (the
auditor never compiles anything), so it also runs in the no-jax CI
analysis job.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"
REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def fmt_bytes(n):
    for u in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{u}"
        n /= 1024
    return f"{n:.1f}PB"


def load(tag_filter=""):
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        tag = p.stem.split("16x16")[-1].lstrip("_")
        if (tag or "") != tag_filter:
            continue
        rows.append(d)
    return rows


def _audit():
    try:
        from repro.analysis import kernel_audit
    except ImportError:                      # script run without PYTHONPATH
        sys.path.insert(0, str(REPO_ROOT / "src"))
        from repro.analysis import kernel_audit
    return kernel_audit


def vmem_section() -> list:
    """Print the audited per-kernel VMEM table; returns the reports."""
    ka = _audit()
    reports = ka.audit_all()
    budget = ka.DEFAULT_VMEM_BUDGET
    print(f"## Kernel VMEM working sets (static audit, "
          f"{budget // 2**20} MiB budget)")
    print()
    print("| kernel | case | grid | points | vmem/step | % budget "
          "| checks |")
    print("|---|---|---|---|---|---|---|")
    for r in reports:
        status = "ok" if r.ok else ",".join(
            sorted({f.check for f in r.findings}))
        print(f"| {r.kernel} | {r.case} | {'x'.join(map(str, r.grid))} "
              f"| {r.grid_points} | {fmt_bytes(r.vmem_bytes)} "
              f"| {100 * r.vmem_bytes / budget:.1f}% | {status} |")
    print()
    tags = {t for r in reports for t in r.tags}
    print(f"corpus: {len(reports)} case(s), "
          f"{len({r.kernel for r in reports})} kernel(s); tags: "
          f"{', '.join(sorted(tags)) or '-'}")
    return reports


def write_vmem_bench() -> pathlib.Path:
    """Commit the audited VMEM table as ``BENCH_kernel_vmem.json``."""
    ka = _audit()
    reports = ka.audit_all()
    budget = ka.DEFAULT_VMEM_BUDGET
    tags = {t for r in reports for t in r.tags}
    results = {}
    for r in reports:
        results.setdefault(r.kernel, {})[r.case] = {
            "grid": list(r.grid), "grid_points": r.grid_points,
            "vmem_bytes": r.vmem_bytes,
        }
    payload = {
        "config": {
            "budget_bytes": budget,
            "kernels": sorted({r.kernel for r in reports}),
            "cases": len(reports),
        },
        "results": results,
        "acceptance": {
            "audit_clean": all(r.ok for r in reports),
            "within_budget": all(r.vmem_bytes <= budget
                                 for r in reports),
            "covers_m_gt_4096": "m_gt_4096" in tags,
            "covers_slack_gt_1": "slack_gt_1" in tags,
        },
    }
    # lazy: benchmarks.common imports jax at module top, and the
    # schema-checked writer is all we need from it
    from benchmarks.common import write_bench_json
    return write_bench_json("kernel_vmem", payload)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="EXPERIMENTS.md roofline tables + audited kernel "
                    "VMEM section")
    ap.add_argument("tag", nargs="?", default="",
                    help="dry-run tag filter (positional, legacy)")
    ap.add_argument("--vmem", action="store_true",
                    help="only print the audited kernel VMEM table "
                         "(jax-free)")
    ap.add_argument("--write-bench", action="store_true",
                    help="write BENCH_kernel_vmem.json from the audit")
    args = ap.parse_args(argv)

    if args.write_bench:
        path = write_vmem_bench()
        print(f"wrote {path}")
        return 0
    if args.vmem:
        vmem_section()
        return 0

    rows = load(args.tag)
    single = [r for r in rows if r["mesh"] == "16x16" and "roofline" in r]
    multi = [r for r in rows if r["mesh"] == "2x16x16"]

    print(f"## Roofline (single-pod 16x16, {len(single)} cells"
          + (f", tag={args.tag})" if args.tag else ")"))
    print()
    print("| arch | shape | c (s) | m (s) | x (s) | dominant | "
          "MODEL_FLOPS | useful/HLO | roofline frac | mem/dev arg+tmp |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(single, key=lambda r: (r["arch"], r["shape"])):
        rf = r["roofline"]
        mem = r.get("memory", {})
        memstr = (fmt_bytes(mem.get("argument_bytes", 0)) + "+" +
                  fmt_bytes(mem.get("temp_bytes", 0))
                  if "argument_bytes" in mem else "n/a")
        print(f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3g} "
              f"| {rf['memory_s']:.3g} | {rf['collective_s']:.3g} "
              f"| {rf['dominant'][:-2]} | {rf['model_flops_total']:.3g} "
              f"| {rf['useful_flops_ratio']:.2f} "
              f"| {rf['roofline_fraction']:.4f} | {memstr} |")

    print()
    print(f"## Multi-pod proof (2x16x16 = 512 chips, {len(multi)} cells)")
    print()
    print("| arch | shape | compile_s | mem/dev arg+tmp |")
    print("|---|---|---|---|")
    for r in sorted(multi, key=lambda r: (r["arch"], r["shape"])):
        mem = r.get("memory", {})
        memstr = (fmt_bytes(mem.get("argument_bytes", 0)) + "+" +
                  fmt_bytes(mem.get("temp_bytes", 0))
                  if "argument_bytes" in mem else "n/a")
        print(f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f} "
              f"| {memstr} |")

    print()
    vmem_section()
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
