"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
import pathlib
import sys

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"


def fmt_bytes(n):
    for u in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{u}"
        n /= 1024
    return f"{n:.1f}PB"


def load(tag_filter=""):
    rows = []
    for p in sorted(RESULTS.glob("*.json")):
        d = json.loads(p.read_text())
        tag = p.stem.split("16x16")[-1].lstrip("_")
        if (tag or "") != tag_filter:
            continue
        rows.append(d)
    return rows


def main(argv=None) -> int:
    tag = argv[0] if argv else ""
    rows = load(tag)
    single = [r for r in rows if r["mesh"] == "16x16" and "roofline" in r]
    multi = [r for r in rows if r["mesh"] == "2x16x16"]

    print(f"## Roofline (single-pod 16x16, {len(single)} cells"
          + (f", tag={tag})" if tag else ")"))
    print()
    print("| arch | shape | c (s) | m (s) | x (s) | dominant | "
          "MODEL_FLOPS | useful/HLO | roofline frac | mem/dev arg+tmp |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(single, key=lambda r: (r["arch"], r["shape"])):
        rf = r["roofline"]
        mem = r.get("memory", {})
        memstr = (fmt_bytes(mem.get("argument_bytes", 0)) + "+" +
                  fmt_bytes(mem.get("temp_bytes", 0))
                  if "argument_bytes" in mem else "n/a")
        print(f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3g} "
              f"| {rf['memory_s']:.3g} | {rf['collective_s']:.3g} "
              f"| {rf['dominant'][:-2]} | {rf['model_flops_total']:.3g} "
              f"| {rf['useful_flops_ratio']:.2f} "
              f"| {rf['roofline_fraction']:.4f} | {memstr} |")

    print()
    print(f"## Multi-pod proof (2x16x16 = 512 chips, {len(multi)} cells)")
    print()
    print("| arch | shape | compile_s | mem/dev arg+tmp |")
    print("|---|---|---|---|")
    for r in sorted(multi, key=lambda r: (r["arch"], r["shape"])):
        mem = r.get("memory", {})
        memstr = (fmt_bytes(mem.get("argument_bytes", 0)) + "+" +
                  fmt_bytes(mem.get("temp_bytes", 0))
                  if "argument_bytes" in mem else "n/a")
        print(f"| {r['arch']} | {r['shape']} | {r['compile_s']:.0f} "
              f"| {memstr} |")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
