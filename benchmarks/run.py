"""Run every benchmark: one per paper table/figure + the roofline summary.

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip the MARL accuracy sweep (slowest)")
    args = ap.parse_args(argv)

    from benchmarks import (fig10_osel, fig11_throughput, fig12_breakdown,
                            fig13_speedup, fig14_serving, table1_balance)
    jobs = [
        ("fig10_osel (OSEL cycles/memory)", fig10_osel.main),
        ("table1_balance (workload deviation)", table1_balance.main),
        # --no-write: the committed BENCH_fig11_throughput.json carries the
        # --async overlap sweep; only an explicit --async run refreshes it
        ("fig11_throughput (accelerator model)",
         lambda: fig11_throughput.main(write=False)),
        ("fig12_breakdown (sparse-gen share)", fig12_breakdown.main),
        ("fig13_speedup (sparse vs dense)", fig13_speedup.main),
        # --no-write: the committed BENCH_serving.json is refreshed only
        # by an explicit benchmarks.fig14_serving run
        ("fig14_serving (continuous batching)",
         lambda: fig14_serving.main(["--no-write"])),
    ]
    if not args.fast:
        from benchmarks import fig9_accuracy
        jobs.append(("fig9_accuracy (MARL accuracy vs sparsity)",
                     lambda: fig9_accuracy.main(["--no-write"])))

    failures = 0
    for name, fn in jobs:
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"=== done in {time.time() - t0:.1f}s ===")
        except Exception:
            failures += 1
            traceback.print_exc()
    print(f"\n{len(jobs) - failures}/{len(jobs)} benchmarks succeeded")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
