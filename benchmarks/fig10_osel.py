"""Fig. 10 — OSEL sparse-data-generation efficiency (cycles + memory).

Reproduces the paper's claims analytically from the cycle/footprint models
of the FPGA encoding loop (repro.core.osel): OSEL vs the recompute-every-row
baseline on a 128×512 mask, G ∈ {2, 4, 8, 16, 32}.

Paper targets: up to 5.72× cycle reduction, 1.95–6.81× memory compression.
Also times the *vectorized TPU-path* encoder (jit on this host) to show the
index-compare encode is microseconds — the overhead the paper hides
on-chip stays hidden on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, save, timeit
from repro.core.osel import cycle_model, encode, footprint_model

M, N = 128, 512


def main() -> dict:
    out = {"cells": []}
    row("# fig10_osel: mask", f"{M}x{N}")
    row("G", "base_cycles", "osel_cycles", "cycle_speedup",
        "dense_bytes", "osel_bytes", "mem_compression", "encode_us")
    best_cyc, best_mem = 0.0, 0.0
    for g in (2, 4, 8, 16, 32):
        base = cycle_model(M, N, g, use_osel=False)
        osel = cycle_model(M, N, g, use_osel=True)
        dense = footprint_model(M, N, g, use_grouping=False)
        sparse = footprint_model(M, N, g, use_grouping=True)
        cyc = base["total"] / osel["total"]
        mem = dense["total"] / sparse["total"]
        best_cyc, best_mem = max(best_cyc, cyc), max(best_mem, mem)

        key = jax.random.PRNGKey(g)
        ig_idx = jax.random.randint(key, (M,), 0, g, jnp.int32)
        og_idx = jax.random.randint(jax.random.fold_in(key, 1), (N,), 0, g,
                                    jnp.int32)
        enc = jax.jit(lambda a, b, g=g: encode(a, b, g))
        us = timeit(enc, ig_idx, og_idx) * 1e6

        row(g, base["total"], osel["total"], f"{cyc:.2f}",
            dense["total"], int(sparse["total"]), f"{mem:.2f}",
            f"{us:.1f}")
        out["cells"].append({
            "G": g, "base_cycles": base["total"],
            "osel_cycles": osel["total"], "cycle_speedup": cyc,
            "osel_breakdown": osel, "mem_dense": dense["total"],
            "mem_osel": sparse["total"], "mem_compression": mem,
            "mem_breakdown": sparse, "tpu_encode_us": us})
    out["max_cycle_speedup"] = best_cyc
    out["max_mem_compression"] = best_mem
    row("# paper: cycles up to 5.72x, memory 1.95-6.81x; measured:",
        f"{best_cyc:.2f}x", f"{best_mem:.2f}x")
    save("fig10_osel", out)
    return out


if __name__ == "__main__":
    main()
