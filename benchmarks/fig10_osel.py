"""Fig. 10 — OSEL sparse-data-generation efficiency (cycles + memory).

Reproduces the paper's claims analytically from the cycle/footprint models
of the FPGA encoding loop (repro.core.osel): OSEL vs the recompute-every-row
baseline on a 128×512 mask, G ∈ {2, 4, 8, 16, 32}.

Paper targets: up to 5.72× cycle reduction, 1.95–6.81× memory compression.
Also times the *vectorized TPU-path* encoder (jit on this host) to show the
index-compare encode is microseconds — the overhead the paper hides
on-chip stays hidden on TPU — and *measures* the full plan encode
(``make_plan``) both ways: the old lexsort/searchsorted idiom (generic XLA
ops outside any kernel) vs the ``plan_encode`` Pallas kernel, interleaved
(`timeit_interleaved`) so host timing drift hits both variants equally.
On a CPU host the kernel runs in interpret mode, so treat the columns as a
structural comparison there; on TPU they are the real device encode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, save, timeit, timeit_interleaved
from repro import kernels as kernels_mod
from repro.core.grouped import make_plan
from repro.core.osel import cycle_model, encode, footprint_model

M, N = 128, 512


def _plan_timers(ig, og):
    """Two compiled make_plan variants: lexsort reference vs Pallas encode.

    The impl is baked at trace time (the shared reference-impl switch), so
    each closure is traced under its mode once and then timed round-robin.
    """
    lex = jax.jit(lambda a, b: make_plan(a, b))
    with kernels_mod.use_reference_impl():
        jax.block_until_ready(lex(ig, og))       # trace with the lexsort
    ker = jax.jit(lambda a, b: make_plan(a, b))
    jax.block_until_ready(ker(ig, og))           # trace with the kernel
    return {"lexsort": lex, "pallas": ker}


def main() -> dict:
    out = {"cells": []}
    row("# fig10_osel: mask", f"{M}x{N}")
    row("G", "base_cycles", "osel_cycles", "cycle_speedup",
        "dense_bytes", "osel_bytes", "mem_compression", "encode_us",
        "plan_lexsort_us", "plan_pallas_us")
    best_cyc, best_mem = 0.0, 0.0
    for g in (2, 4, 8, 16, 32):
        base = cycle_model(M, N, g, use_osel=False)
        osel = cycle_model(M, N, g, use_osel=True)
        dense = footprint_model(M, N, g, use_grouping=False)
        sparse = footprint_model(M, N, g, use_grouping=True)
        cyc = base["total"] / osel["total"]
        mem = dense["total"] / sparse["total"]
        best_cyc, best_mem = max(best_cyc, cyc), max(best_mem, mem)

        key = jax.random.PRNGKey(g)
        ig_idx = jax.random.randint(key, (M,), 0, g, jnp.int32)
        og_idx = jax.random.randint(jax.random.fold_in(key, 1), (N,), 0, g,
                                    jnp.int32)
        enc = jax.jit(lambda a, b, g=g: encode(a, b, g))
        us = timeit(enc, ig_idx, og_idx) * 1e6

        # measured device encode: full make_plan, lexsort vs Pallas
        ig = jax.random.normal(jax.random.fold_in(key, 2), (M, g))
        og = jax.random.normal(jax.random.fold_in(key, 3), (g, N))
        best = timeit_interleaved(_plan_timers(ig, og), ig, og)
        lex_us, ker_us = best["lexsort"] * 1e6, best["pallas"] * 1e6

        row(g, base["total"], osel["total"], f"{cyc:.2f}",
            dense["total"], int(sparse["total"]), f"{mem:.2f}",
            f"{us:.1f}", f"{lex_us:.1f}", f"{ker_us:.1f}")
        out["cells"].append({
            "G": g, "base_cycles": base["total"],
            "osel_cycles": osel["total"], "cycle_speedup": cyc,
            "osel_breakdown": osel, "mem_dense": dense["total"],
            "mem_osel": sparse["total"], "mem_compression": mem,
            "mem_breakdown": sparse, "tpu_encode_us": us,
            "plan_lexsort_us": lex_us, "plan_pallas_us": ker_us,
            "plan_encode_interpret": jax.default_backend() != "tpu"})
    out["max_cycle_speedup"] = best_cyc
    out["max_mem_compression"] = best_mem
    row("# paper: cycles up to 5.72x, memory 1.95-6.81x; measured:",
        f"{best_cyc:.2f}x", f"{best_mem:.2f}x")
    save("fig10_osel", out)
    return out


if __name__ == "__main__":
    main()
