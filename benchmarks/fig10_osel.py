"""Fig. 10 — OSEL sparse-data-generation efficiency (cycles + memory).

Reproduces the paper's claims analytically from the cycle/footprint models
of the FPGA encoding loop (repro.core.osel): OSEL vs the recompute-every-row
baseline on a 128×512 mask, G ∈ {2, 4, 8, 16, 32}.

Paper targets: up to 5.72× cycle reduction, 1.95–6.81× memory compression.
Also times the *vectorized TPU-path* encoder (jit on this host) to show the
index-compare encode is microseconds — the overhead the paper hides
on-chip stays hidden on TPU — and *measures* the full plan encode
(``make_plan``) both ways: the old lexsort/searchsorted idiom (generic XLA
ops outside any kernel) vs the ``plan_encode`` Pallas kernel, interleaved
(`timeit_interleaved`) so host timing drift hits both variants equally.

The M-sweep (committed artifact ``BENCH_fig10_osel.json``) crosses the old
4096-item tile cap that used to force a lexsort fallback. Above it, the
quantity that matters is the *amortized refresh window* — the paper's
encode-once/consume-many dataflow: one plan encode (+ one weight
compaction, post-PR) followed by ``WINDOW`` grouped consume steps.

* pre-PR:  lexsort encode, then per-step XLA gathers of both operands
  (``grouped_matmul``) — W re-gathered every step;
* fused:   tiled-kernel encode + ``compact_weights`` once, then per-step
  ``grouped_matmul_fused`` reading the cached ``(G, cap)`` compact weights
  straight from the encode output (the OSEL→core handoff).

``kernel_beats_lexsort_above_4096`` asserts the fused window wins at every
M > 4096 cell. On a CPU host both kernels run in interpret mode (the
isolated encode *loses* there — the committed per-piece timings show it);
the window still flips because the per-step W-gather the fused path
retires outweighs the interpreted encode deficit.

``--check`` is the CI gate: bitwise oversize encode + fused-vs-gather
grouped step in interpret mode, plus schema/flag validation of the
committed artifact. No timing — CI boxes are too noisy to gate on a
single-digit-percent wall-clock margin.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp

from benchmarks.common import (REPO_ROOT, row, save, timeit,
                               timeit_interleaved, write_bench_json)
from repro import kernels as kernels_mod
from repro.core.grouped import make_plan
from repro.core.osel import cycle_model, encode, footprint_model
from repro.kernels.flgw_matmul import ops as fops

M, N = 128, 512

# M-sweep across the old 4096-item cap; N scales with M so the consume
# step stays W-gather-bound (the contrast the fused path retires).
SWEEP = (2048, 4096, 8192)
SWEEP_G, SWEEP_B, SWEEP_SLACK = 8, 4, 1.25
WINDOW = 8          # consume steps per encode (decode steps per refresh)


def _plan_timers(ig, og, slack=1.0):
    """Two compiled make_plan variants: lexsort reference vs Pallas encode.

    The impl is baked at trace time (the shared reference-impl switch), so
    each closure is traced under its mode once and then timed round-robin.
    """
    lex = jax.jit(lambda a, b: make_plan(a, b, slack))
    with kernels_mod.use_reference_impl():
        jax.block_until_ready(lex(ig, og))       # trace with the lexsort
    ker = jax.jit(lambda a, b: make_plan(a, b, slack))
    jax.block_until_ready(ker(ig, og))           # trace with the kernel
    return {"lexsort": lex, "pallas": ker}


def _sweep_inputs(m, n, g=SWEEP_G, b=SWEEP_B):
    key = jax.random.PRNGKey(m)
    x = jax.random.normal(key, (b, m))
    w = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    ig = jax.random.normal(jax.random.fold_in(key, 2), (m, g))
    og = jax.random.normal(jax.random.fold_in(key, 3), (g, n))
    return x, w, ig, og


def _sweep_cell(m, reps=5):
    """One amortized-window cell: encode + WINDOW consume steps, both ways."""
    n = m // 4
    x, w, ig, og = _sweep_inputs(m, n)
    enc = timeit_interleaved(_plan_timers(ig, og, SWEEP_SLACK), ig, og,
                             reps=reps, stat="median")
    plan = make_plan(ig, og, SWEEP_SLACK)
    t_compact = timeit(jax.jit(fops.compact_weights), w, plan.row_ids,
                       plan.col_ids, plan.row_valid, plan.col_valid)
    wc = fops.compact_weights(w, plan.row_ids, plan.col_ids,
                              plan.row_valid, plan.col_valid)
    gather = jax.jit(lambda x, w: fops.grouped_matmul(
        x, w, plan.row_ids, plan.col_ids, plan.row_valid, plan.col_valid,
        interpret=True))
    fused = jax.jit(lambda x, wc: fops.grouped_matmul_fused(
        x, wc, plan.row_ids, plan.row_valid, plan.col_ids, plan.col_valid,
        n=n, interpret=True))
    consume = timeit_interleaved(
        {"gather": lambda: gather(x, w), "fused": lambda: fused(x, wc)},
        reps=reps, stat="median")
    pre = enc["lexsort"] + WINDOW * consume["gather"]
    post = enc["pallas"] + t_compact + WINDOW * consume["fused"]
    return {"M": m, "N": n, "above_cap": m > 4096,
            "enc_lexsort_s": enc["lexsort"], "enc_kernel_s": enc["pallas"],
            "compact_s": t_compact,
            "consume_gather_s": consume["gather"],
            "consume_fused_s": consume["fused"],
            "window_pre_pr_s": pre, "window_fused_s": post,
            "window_speedup": pre / post}


def _oversize_bitwise(m=4352, g=SWEEP_G, b=SWEEP_B, n=512):
    """M > old cap: kernel encode bitwise vs lexsort, and the fused
    grouped step bitwise vs the XLA-gather step — both interpret mode."""
    x, w, ig, og = _sweep_inputs(m, n, g, b)
    plan = make_plan(ig, og, SWEEP_SLACK)
    with kernels_mod.use_reference_impl():
        ref = make_plan(ig, og, SWEEP_SLACK)
    enc_ok = all(bool(jnp.array_equal(a, b)) for a, b in
                 zip(jax.tree.leaves(plan), jax.tree.leaves(ref)))
    wc = fops.compact_weights(w, plan.row_ids, plan.col_ids,
                              plan.row_valid, plan.col_valid)
    y_fused = fops.grouped_matmul_fused(
        x, wc, plan.row_ids, plan.row_valid, plan.col_ids, plan.col_valid,
        n=n, interpret=True)
    y_gather = fops.grouped_matmul(
        x, w, plan.row_ids, plan.col_ids, plan.row_valid, plan.col_valid,
        interpret=True)
    step_ok = bool(jnp.array_equal(y_fused, y_gather))
    return enc_ok, step_ok


def check() -> int:
    """CI gate: oversize encode + fused grouped step, bitwise, interpret;
    plus the committed artifact's schema and acceptance flags."""
    enc_ok, step_ok = _oversize_bitwise()
    row("# check: oversize encode bitwise", enc_ok)
    row("# check: fused grouped step bitwise", step_ok)
    ok = enc_ok and step_ok
    path = REPO_ROOT / "BENCH_fig10_osel.json"
    if not path.exists():
        row("# check: MISSING", str(path))
        return 1
    doc = json.loads(path.read_text())
    flags = doc.get("acceptance", {})
    for name, val in flags.items():
        row(f"# check: committed acceptance[{name}]", val)
        ok = ok and val is True
    ok = ok and {"config", "results"} <= doc.keys()
    return 0 if ok else 1


def main() -> dict:
    out = {"cells": []}
    row("# fig10_osel: mask", f"{M}x{N}")
    row("G", "base_cycles", "osel_cycles", "cycle_speedup",
        "dense_bytes", "osel_bytes", "mem_compression", "encode_us",
        "plan_lexsort_us", "plan_pallas_us")
    best_cyc, best_mem = 0.0, 0.0
    for g in (2, 4, 8, 16, 32):
        base = cycle_model(M, N, g, use_osel=False)
        osel = cycle_model(M, N, g, use_osel=True)
        dense = footprint_model(M, N, g, use_grouping=False)
        sparse = footprint_model(M, N, g, use_grouping=True)
        cyc = base["total"] / osel["total"]
        mem = dense["total"] / sparse["total"]
        best_cyc, best_mem = max(best_cyc, cyc), max(best_mem, mem)

        key = jax.random.PRNGKey(g)
        ig_idx = jax.random.randint(key, (M,), 0, g, jnp.int32)
        og_idx = jax.random.randint(jax.random.fold_in(key, 1), (N,), 0, g,
                                    jnp.int32)
        enc = jax.jit(lambda a, b, g=g: encode(a, b, g))
        us = timeit(enc, ig_idx, og_idx) * 1e6

        # measured device encode: full make_plan, lexsort vs Pallas
        ig = jax.random.normal(jax.random.fold_in(key, 2), (M, g))
        og = jax.random.normal(jax.random.fold_in(key, 3), (g, N))
        best = timeit_interleaved(_plan_timers(ig, og), ig, og,
                                  stat="median")
        lex_us, ker_us = best["lexsort"] * 1e6, best["pallas"] * 1e6

        row(g, base["total"], osel["total"], f"{cyc:.2f}",
            dense["total"], int(sparse["total"]), f"{mem:.2f}",
            f"{us:.1f}", f"{lex_us:.1f}", f"{ker_us:.1f}")
        out["cells"].append({
            "G": g, "base_cycles": base["total"],
            "osel_cycles": osel["total"], "cycle_speedup": cyc,
            "osel_breakdown": osel, "mem_dense": dense["total"],
            "mem_osel": sparse["total"], "mem_compression": mem,
            "mem_breakdown": sparse, "tpu_encode_us": us,
            "plan_lexsort_us": lex_us, "plan_pallas_us": ker_us,
            "plan_encode_interpret": jax.default_backend() != "tpu"})
    out["max_cycle_speedup"] = best_cyc
    out["max_mem_compression"] = best_mem
    row("# paper: cycles up to 5.72x, memory 1.95-6.81x; measured:",
        f"{best_cyc:.2f}x", f"{best_mem:.2f}x")

    # -- M-sweep across the old 4096 tile cap (amortized refresh window) --
    row(f"# M-sweep: g={SWEEP_G} b={SWEEP_B} slack={SWEEP_SLACK}"
        f" window={WINDOW} (encode + K consume steps, medians)")
    row("M", "N", "enc_lex_ms", "enc_ker_ms", "compact_ms",
        "consume_gather_ms", "consume_fused_ms", "window_speedup")
    sweep = []
    for m in SWEEP:
        c = _sweep_cell(m)
        sweep.append(c)
        row(c["M"], c["N"], f"{c['enc_lexsort_s'] * 1e3:.1f}",
            f"{c['enc_kernel_s'] * 1e3:.1f}", f"{c['compact_s'] * 1e3:.1f}",
            f"{c['consume_gather_s'] * 1e3:.1f}",
            f"{c['consume_fused_s'] * 1e3:.1f}",
            f"{c['window_speedup']:.3f}")
    out["sweep"] = sweep
    enc_ok, step_ok = _oversize_bitwise()
    above = [c for c in sweep if c["above_cap"]]
    beats = bool(above) and all(c["window_speedup"] > 1.0 for c in above)
    row("# kernel_beats_lexsort_above_4096:", beats,
        "(amortized window; per-piece medians committed)")
    save("fig10_osel", out)
    write_bench_json("fig10_osel", {
        "config": {"mask_m": M, "mask_n": N, "sweep_g": SWEEP_G,
                   "sweep_b": SWEEP_B, "sweep_slack": SWEEP_SLACK,
                   "window": WINDOW, "backend": jax.default_backend(),
                   "interpret": jax.default_backend() != "tpu"},
        "results": {"max_cycle_speedup": best_cyc,
                    "max_mem_compression": best_mem, "sweep": sweep},
        "acceptance": {
            "kernel_beats_lexsort_above_4096": beats,
            "oversize_encode_bitwise": bool(enc_ok),
            "fused_step_bitwise": bool(step_ok),
        }})
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="CI gate: bitwise oversize encode + fused step, "
                         "plus committed-artifact validation (no timing)")
    if ap.parse_args().check:
        sys.exit(check())
    main()
