"""Fault tolerance: preemption hooks, transient-error retry, step runner.

The training loop is a pure function of (state, batch) and the data
pipeline is a pure function of step — so fault tolerance reduces to three
small mechanisms:

* ``PreemptionGuard`` — SIGTERM/SIGINT sets a flag; the loop checkpoints at
  the *next step boundary* and exits cleanly (TPU preemption notice).
* ``retry_transient`` — re-runs a step on transient runtime errors
  (collective timeout / interconnect hiccup). Deterministic data means a
  retry is bit-identical, and donated buffers are rebuilt from the last
  good state.
* ``StepRunner`` — wires them together with periodic + on-preemption
  checkpointing; on restart it resumes from the latest manifest.

Straggler mitigation at the step level is handled *inside* the step (the
paper's row-based load balancing / capacity-bounded MoE dispatch give every
shard the same op schedule — no data-dependent shapes, so no shard ever
waits on a slow peer's recompile); across steps, the deterministic replay
makes restart-on-straggler equivalent to failure recovery.
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Optional

import jax

TRANSIENT_MARKERS = ("DEADLINE_EXCEEDED", "UNAVAILABLE", "ABORTED",
                     "RESOURCE_EXHAUSTED: Socket", "connection reset")


class PreemptionGuard:
    """Latches SIGTERM/SIGINT; ``should_stop`` is polled at step boundaries."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._flag = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:          # not in main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._flag = True

    @property
    def should_stop(self) -> bool:
        return self._flag

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


def retry_transient(fn: Callable, *args, retries: int = 3,
                    backoff_s: float = 1.0, on_retry=None, **kwargs):
    """Run ``fn``; retry on errors whose message looks transient."""
    attempt = 0
    while True:
        try:
            return fn(*args, **kwargs)
        except Exception as e:  # noqa: BLE001 — classify then re-raise
            msg = str(e)
            transient = any(m in msg for m in TRANSIENT_MARKERS)
            if not transient or attempt >= retries:
                raise
            attempt += 1
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(backoff_s * attempt)


class StepRunner:
    """Checkpointing step loop: periodic saves, preemption-safe exit,
    restart-from-latest. ``step_fn(state, batch) -> (state, metrics)``."""

    def __init__(self, step_fn, ckpt_dir, *, save_every: int = 100,
                 keep: int = 3, guard: Optional[PreemptionGuard] = None):
        from repro import checkpoint as ckpt
        self._ckpt = ckpt
        self.step_fn = step_fn
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.keep = keep
        self.guard = guard or PreemptionGuard()

    def restore_or(self, state, shardings=None, restore_fn=None):
        """Resume from the latest checkpoint if one exists.

        ``restore_fn(state, shardings) -> (state, step)`` overrides the
        plain full-tree restore — the TrainState path passes
        ``repro.train.state.restore_state`` here so derived leaves
        (cached FLGW plans) are re-encoded from the restored params
        rather than loaded stale, and pre-plans manifests migrate.
        """
        latest = self._ckpt.latest_step(self.ckpt_dir)
        if latest is None:
            return state, 0
        if restore_fn is not None:
            return restore_fn(state, shardings)
        state, step = self._ckpt.restore_checkpoint(
            self.ckpt_dir, state, shardings=shardings)
        return state, step

    def run(self, state, batches, *, start_step: int = 0,
            max_steps: Optional[int] = None, log_every: int = 0):
        step = start_step
        history = []
        for batch in batches:
            if max_steps is not None and step >= max_steps:
                break
            state, metrics = retry_transient(self.step_fn, state, batch)
            step += 1
            history.append(metrics)
            if log_every and step % log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                print(f"step {step}: {m}", flush=True)
            stop = self.guard.should_stop
            if stop or step % self.save_every == 0:
                self._ckpt.save_checkpoint(self.ckpt_dir, step, state,
                                           keep=self.keep)
            if stop:
                break
        return state, step, history
