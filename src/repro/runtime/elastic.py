"""Elastic remesh: rebuild the mesh from the live device set and reshard.

After a node failure shrinks the fleet (512 -> 448 -> ...), training resumes
on the survivors: ``remesh_state`` builds a new (data, model) mesh from
whatever ``jax.devices()`` reports, recomputes every leaf's NamedSharding
from the *logical* specs (the rules table is mesh-shape agnostic — that is
the point of the logical indirection) and device_puts the state across.

Combined with the deterministic data pipeline (batch = f(seed, step)) and
checkpointed step counter, an elastic shrink/grow is semantically a restart:
no optimizer state is lost, the global batch stays fixed (per-device batch
grows), and the collective topology is rebuilt by GSPMD at the next jit.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.launch.mesh import make_mesh_from_devices
from repro.sharding import partition


def remesh_state(state, specs, *, devices=None, model: int = 0,
                 old_mesh=None):
    """Reshard ``state`` (pytree matching ``specs``) onto a fresh mesh.

    Returns (new_state, new_mesh). Works host-locally in tests (1 device)
    and on any surviving device set in production.
    """
    mesh = make_mesh_from_devices(devices, model=model)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    shardings = partition.constrained_shardings(specs, abstract, mesh)
    new_state = jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, shardings)
    return new_state, mesh
