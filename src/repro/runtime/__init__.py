from repro.runtime.fault import (PreemptionGuard, retry_transient,
                                 StepRunner)
from repro.runtime.elastic import remesh_state

__all__ = ["PreemptionGuard", "retry_transient", "StepRunner",
           "remesh_state"]
