"""Runtime trace/compile contracts.

The repo's amortization claims are *count* claims: ``make_plan`` traces
once per FLGW layer per refresh, zero times per decode step; a jitted
step compiles once per shape and never again mid-run. Before this module
every test enforcing a count claim hand-rolled the same monkeypatch::

    calls = {"n": 0}
    real = grouped.make_plan
    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)
    monkeypatch.setattr(grouped, "make_plan", counting)

and nothing at all watched for silent recompiles in the serving/async
hot loops. This module is the shared replacement:

* :func:`trace_counter` — the counting monkeypatch as a context manager
  (count, reset, call-through semantics identical to the old idiom);
* :func:`assert_max_traces` — the common assertion form in one line;
* :func:`no_retrace` — a compile monitor built on ``jax.log_compiles``:
  every XLA compile inside the context is recorded, and leaving the
  context raises :class:`RetraceError` if any function compiled more
  than once (a mid-run recompile — shape instability, a cache-defeating
  weak-ref loss, or an accidentally-traced Python bool). This is the
  engine behind the opt-in ``debug_contracts=True`` hooks on
  ``ServeSession``/``Engine`` and ``marl.async_train``.
"""
from __future__ import annotations

import contextlib
import logging
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax

__all__ = [
    "ContractViolation", "RetraceError", "TraceCounter", "CompileMonitor",
    "trace_counter", "assert_max_traces", "no_retrace",
]


class ContractViolation(AssertionError):
    """A runtime trace/compile contract did not hold."""


class RetraceError(ContractViolation):
    """A jitted function compiled more than once inside ``no_retrace``."""


# ---------------------------------------------------------------------------
# trace counting (the make_plan idiom, shared)
# ---------------------------------------------------------------------------

@dataclass
class TraceCounter:
    """Live handle yielded by :func:`trace_counter`.

    ``count`` increments on every call of the wrapped attribute —
    including calls under ``jax.eval_shape``/``jit`` tracing, which is
    the point: the number of *traces* is the amortization contract.
    """
    module: object = None
    attr: str = ""
    count: int = 0
    calls: List[Tuple[tuple, dict]] = field(default_factory=list)

    def reset(self) -> None:
        self.count = 0
        self.calls.clear()

    def __int__(self) -> int:
        return self.count


@contextlib.contextmanager
def trace_counter(module, attr: str, *, record_args: bool = False):
    """Count calls to ``module.attr`` while delegating to the original.

    The one replacement for the per-file ``counting`` +
    ``monkeypatch.setattr(module, attr, counting)`` copies::

        with trace_counter(grouped, "make_plan") as calls:
            jax.eval_shape(step, state, batch)
        assert calls.count == n_layers

    The original attribute is restored on exit even if the body raises.
    ``record_args=True`` additionally keeps ``(args, kwargs)`` per call
    on ``calls.calls`` for tests that assert on arguments.
    """
    real = getattr(module, attr)
    counter = TraceCounter(module=module, attr=attr)

    def counting(*a, **kw):
        counter.count += 1
        if record_args:
            counter.calls.append((a, kw))
        return real(*a, **kw)

    counting.__name__ = getattr(real, "__name__", attr)
    counting.__wrapped__ = real
    setattr(module, attr, counting)
    try:
        yield counter
    finally:
        setattr(module, attr, real)


@contextlib.contextmanager
def assert_max_traces(module, attr: str, n: int, *,
                      exactly: bool = False):
    """Context form of the count assertion: at most (or exactly) ``n``
    traces of ``module.attr`` inside the block, else
    :class:`ContractViolation`.
    """
    with trace_counter(module, attr) as counter:
        yield counter
    if exactly and counter.count != n:
        raise ContractViolation(
            f"{getattr(module, '__name__', module)}.{attr} traced "
            f"{counter.count} time(s); contract requires exactly {n}")
    if counter.count > n:
        raise ContractViolation(
            f"{getattr(module, '__name__', module)}.{attr} traced "
            f"{counter.count} time(s); contract allows at most {n}")


# ---------------------------------------------------------------------------
# recompile guard (jax.log_compiles)
# ---------------------------------------------------------------------------

# jax logs one WARNING-level record per XLA compile when jax_log_compiles
# is on: "Compiling <name> with global shapes and types [...]" — emitted
# by the pxla/dispatch internals. The logger names are version-dependent
# internals, so we hook every plausible one; the message prefix is the
# stable part.
_COMPILE_LOGGERS = (
    "jax._src.interpreters.pxla",
    "jax._src.dispatch",
    "jax._src.pjit",
)
_COMPILE_RE = re.compile(r"^Compiling ([^\s]+)")

# Eager jnp/lax/random ops executed outside any user jit compile under
# the *library function's* name — sometimes the public one ("less",
# "select_n", "take_along_axis"), sometimes a private implementation
# helper ("_where" for jnp.where, "_threefry_split" for
# jax.random.split, "_broadcast_arrays") — and the log record is
# indistinguishable from a user jit's. They legitimately compile once
# per operand shape (or per static arg, e.g. the split count):
# host-side bookkeeping around a hot loop — masking a ragged flush,
# stacking a variable-width window, splitting a key — is not the
# retrace class this guard exists for. So compiles whose name matches a
# callable defined in any loaded ``jax.*`` module are exempt from the
# offender check (still recorded on the monitor). The set is rebuilt at
# each context exit so modules imported mid-block are covered. The flip
# side: a user jit that shadows a jax callable name ("where", "scan",
# "update") escapes the guard — name it something else.

def _library_op_names() -> frozenset:
    import sys
    names = set()
    for modname, mod in list(sys.modules.items()):
        if mod is None or not (modname == "jax"
                               or modname.startswith("jax.")):
            continue
        for attr in dir(mod):
            try:
                if callable(getattr(mod, attr, None)):
                    names.add(attr)
            except Exception:      # a broken lazy attribute must not kill us
                pass
    return frozenset(names)


@dataclass
class CompileEvent:
    name: str          # jitted function name as jax logged it
    message: str       # full log record (includes the abstract shapes)


class CompileMonitor:
    """Collects the compile events seen inside a ``no_retrace`` block."""

    def __init__(self) -> None:
        self.events: List[CompileEvent] = []

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self.events:
            out[ev.name] = out.get(ev.name, 0) + 1
        return out

    def shapes(self, name: str) -> List[str]:
        return [ev.message for ev in self.events if ev.name == name]


class _CompileHandler(logging.Handler):
    def __init__(self, monitor: CompileMonitor):
        super().__init__(level=logging.DEBUG)
        self.monitor = monitor

    def emit(self, record: logging.LogRecord) -> None:
        try:
            msg = record.getMessage()
        except Exception:          # a malformed record must not kill the run
            return
        m = _COMPILE_RE.match(msg)
        if m:
            self.monitor.events.append(CompileEvent(m.group(1), msg))


@contextlib.contextmanager
def no_retrace(*, max_compiles: int = 1, allow: Tuple[str, ...] = (),
               label: str = "", monitor: Optional[CompileMonitor] = None):
    """Fail if any jitted function compiles more than ``max_compiles``
    times inside the block.

    The contract behind the serving/async hot loops: after the first
    step of a run compiles each jitted function once per shape, *no*
    further compiles may happen mid-run — a second compile of the same
    function means the loop is feeding shape-unstable inputs (or
    re-tracing through a lost jit cache), exactly the silent stall class
    "Characterizing Speed Performance of MARL" measures. Function names
    in ``allow`` are exempt (e.g. a deliberately polymorphic helper), as
    are eager jnp/lax library ops (see ``_library_op_names``), which
    compile once per shape by design.

    Usage::

        with no_retrace(label="Engine.run") as mon:
            for _ in range(steps):
                tok, cache = session.decode(cache, tok, pos)
        # raises RetraceError if any function compiled twice

    First compiles are allowed (``max_compiles=1``); a warmed-up caller
    can pass ``max_compiles=0`` to forbid any compile at all. Nesting is
    safe; the monitor only sees compiles issued while the block is
    active (on any thread — jax's compile log is process-global, which
    is what makes this catch the threaded async pipeline too).
    """
    mon = monitor if monitor is not None else CompileMonitor()
    handler = _CompileHandler(mon)
    loggers = []
    for name in _COMPILE_LOGGERS:
        lg = logging.getLogger(name)
        # the records arrive at WARNING; make sure they are not filtered
        # out before our handler sees them, and restore the level after
        prev_level = lg.level
        if not lg.isEnabledFor(logging.WARNING):
            lg.setLevel(logging.WARNING)
        lg.addHandler(handler)
        loggers.append((lg, prev_level))
    try:
        with jax.log_compiles(True):
            yield mon
    finally:
        for lg, prev_level in loggers:
            lg.removeHandler(handler)
            lg.setLevel(prev_level)
    library = _library_op_names()
    offenders = {name: n for name, n in mon.counts().items()
                 if n > max_compiles and name not in allow
                 and name not in library}
    if offenders:
        where = f" in {label}" if label else ""
        lines = []
        for name, n in sorted(offenders.items()):
            lines.append(f"  {name}: compiled {n}x "
                         f"(allowed {max_compiles})")
            for msg in mon.shapes(name):
                lines.append(f"    - {msg}")
        raise RetraceError(
            f"recompile contract violated{where}: a jitted step "
            f"recompiled mid-run\n" + "\n".join(lines))
