"""Static analysis + runtime trace/compile contracts for the repo.

Two layers keep the "sparse handling stays exact, hot loop stays
compiled" property mechanical instead of per-test manual:

* :mod:`repro.analysis.lint` — an AST linter with repo-specific rules
  (ANL001..ANL006): module-level ``jax``/``jnp`` array construction in
  importable modules, host-sync idioms inside jitted step factories and
  hot loops, Pallas ``pallas_call`` structural consistency, undeclared
  ``custom_vjp`` static args, visibly mismatched ``lax.scan`` carries,
  and ``pallas_call`` sites with no registered KernelSpec. Run as
  ``python -m repro.analysis.lint src tests benchmarks examples
  [--check]``.
* :mod:`repro.analysis.contracts` — runtime contracts: ``trace_counter``
  (the one replacement for the monkeypatched ``make_plan`` counting
  idiom), ``assert_max_traces`` and ``no_retrace`` (a
  ``jax.log_compiles``-based recompile guard, surfaced as the opt-in
  ``debug_contracts=True`` hook on ``ServeSession`` / ``Engine`` /
  ``async_train``).
* :mod:`repro.analysis.kernel_audit` — a grid/BlockSpec abstract
  interpreter that proves bounds, output coverage, write-disjointness
  and VMEM working-set budgets for every registered Pallas kernel over
  a shape corpus, without compiling anything. Run as ``python -m
  repro.analysis.kernel_audit [--check]``.
"""
__all__ = [
    "ContractViolation", "RetraceError", "assert_max_traces",
    "no_retrace", "trace_counter", "Finding", "lint_file", "lint_paths",
    "AuditFinding", "CaseReport", "GridCase", "KernelSpec", "Operand",
    "audit_all", "load_registry", "register_kernel_spec", "vmem_table",
    "contracts", "lint", "kernel_audit",
]

_EXPORTS = {
    "ContractViolation": "contracts", "RetraceError": "contracts",
    "assert_max_traces": "contracts", "no_retrace": "contracts",
    "trace_counter": "contracts",
    "Finding": "lint", "lint_file": "lint", "lint_paths": "lint",
    "AuditFinding": "kernel_audit", "CaseReport": "kernel_audit",
    "GridCase": "kernel_audit", "KernelSpec": "kernel_audit",
    "Operand": "kernel_audit", "audit_all": "kernel_audit",
    "load_registry": "kernel_audit",
    "register_kernel_spec": "kernel_audit", "vmem_table": "kernel_audit",
}


def __getattr__(name):
    # everything resolves lazily: the lint and kernel-audit CLIs
    # (`python -m repro.analysis.{lint,kernel_audit}`) must not pull in
    # contracts' jax import (the CI analysis job runs without jax
    # installed), and an eager import here would load the submodule
    # twice under runpy (the "found in sys.modules" RuntimeWarning)
    import importlib
    if name in ("contracts", "lint", "kernel_audit"):
        return importlib.import_module(f"repro.analysis.{name}")
    mod = _EXPORTS.get(name)
    if mod is not None:
        return getattr(
            importlib.import_module(f"repro.analysis.{mod}"), name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
