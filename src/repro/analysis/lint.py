"""AST lint pass with repo-specific JAX/Pallas rules.

Run over source roots (``python -m repro.analysis.lint src tests
benchmarks examples``); ``--check`` gates CI against the committed
baseline (``analysis_baseline.txt``). Rules:

=======  ====================================================================
code     what it catches
=======  ====================================================================
ANL001   Module-level ``jax.*``/``jnp.*`` array construction in an
         importable module (sibling ``__init__.py``). Building a device
         array at import time commits the runtime to a backend before
         ``jax.distributed.initialize`` can run — the PR-8 lockout class
         (module constants in the MARL envs blocked multi-host bring-up).
ANL002   Host-device sync idioms (``float()``/``int()``/``bool()`` on
         array-like values, ``.item()``, ``np.asarray``/``np.array``,
         ``jax.device_get``) inside a traced context — a jit/pmap-decorated
         function, a function handed to ``jax.jit``/``lax.scan``/
         ``lax.while_loop``/…, or a step function defined inside a
         ``make_*`` factory — and, second form, per-iteration host
         materialization inside a loop that drives a jitted step (the
         serving tick loop / learner loop), where results should be
         fetched once per window. Loops that call ``block_until_ready``
         are exempt (explicit timing loops).
ANL003   ``pl.pallas_call`` structural inconsistencies that the runtime
         only reports as opaque Mosaic errors (or silently miscompiles in
         interpret mode): BlockSpec index_map arity != grid arity,
         index_map return length != block-shape rank, out_specs rank !=
         out_shape rank, operand count != len(in_specs), scratch dims not
         drawn from any block shape, and ``interpret=`` flags that are
         computed values rather than Python bools (a traced interpret
         flag retraces the kernel every call).
ANL004   ``jax.custom_vjp`` declarations whose static args aren't
         declared: bool/str-defaulted or bool/str-annotated positional
         params missing from ``nondiff_argnums``, out-of-range
         ``nondiff_argnums`` indices, keyword-only params (unsupported by
         custom_vjp), and a custom_vjp primal with no ``defvjp``
         registration in the module.
ANL005   ``lax.scan`` bodies whose carry structure visibly differs
         between input and output (unpack length vs returned tuple
         length vs init literal length), or that don't return a
         ``(carry, ys)`` pair — the runtime error is a deeply-nested
         pytree mismatch; the lint points at the body.
ANL006   ``pl.pallas_call`` sites in modules with no
         :class:`~repro.analysis.kernel_audit.KernelSpec` registration —
         neither a ``register_kernel_spec`` call in the module itself
         nor a sibling ``audit.py`` that registers specs naming this
         module. Unregistered kernels escape the static grid/BlockSpec
         audit (bounds / coverage / write-disjointness / VMEM), so
         registration is mandatory.
=======  ====================================================================

Suppression: trailing ``# noqa: ANL003`` on the offending line (comma
lists and bare ``# noqa`` both work). Accepted findings live in the
baseline file — one ``path|code|stripped source line`` entry per finding,
``#``-comments for justification — so ``--check`` stays green while the
finding stays visible. ``--write-baseline`` emits the current findings in
baseline format. A baseline entry that no longer matches any finding is
*stale* and fails ``--check`` — suppressions must rot away with the code
they covered, not accumulate.
"""
from __future__ import annotations

import argparse
import ast
import os
import re
import sys
from collections import Counter
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["RULES", "Finding", "lint_source", "lint_file", "lint_paths",
           "load_baseline", "format_baseline_entry",
           "stale_baseline_entries", "main"]

RULES = {
    "ANL001": "module-level jax/jnp array construction in an importable "
              "module (locks out jax.distributed.initialize)",
    "ANL002": "host-device sync inside a traced context or a "
              "jitted-step hot loop",
    "ANL003": "pallas_call structural inconsistency",
    "ANL004": "custom_vjp static/nondiff declaration problem",
    "ANL005": "lax.scan carry structure mismatch",
    "ANL006": "pallas_call site with no registered KernelSpec "
              "(escapes the static kernel audit)",
}

# the positive lint fixtures deliberately violate the rules; keep the
# repo-wide run (and CI --check) out of the linter's own test corpus
DEFAULT_EXCLUDES = (os.path.join("tests", "fixtures", "lint"),)

_NOQA_RE = re.compile(
    r"#\s*noqa(?::\s*(?P<codes>[A-Z]+[0-9]+(?:\s*,\s*[A-Z]+[0-9]+)*))?",
    re.IGNORECASE)

# jnp constructors that materialize a device array at call time
_ARRAY_CTORS = {
    "array", "asarray", "zeros", "ones", "full", "empty", "arange",
    "linspace", "logspace", "eye", "identity", "tri", "diag",
    "zeros_like", "ones_like", "full_like", "empty_like", "meshgrid",
}
# jax-level calls that commit the process to a backend at import time
_BACKEND_CALLS = {
    "jax.device_put", "jax.devices", "jax.local_devices",
    "jax.device_count", "jax.local_device_count", "jax.default_backend",
}

# calls whose function-valued arguments run under trace
_TRACER_CONSUMERS = {
    "jax.jit", "jax.pmap", "jax.vmap", "jax.grad", "jax.value_and_grad",
    "jax.checkpoint", "jax.remat", "jax.lax.scan", "jax.lax.cond",
    "jax.lax.while_loop", "jax.lax.fori_loop", "jax.lax.map",
    "jax.lax.switch", "jax.lax.associative_scan",
}

_SYNC_CALLS = {"numpy.asarray", "numpy.array", "jax.device_get"}


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    code: str
    message: str
    source: str = ""          # stripped source line (baseline fingerprint)

    def baseline_key(self) -> Tuple[str, str, str]:
        return (self.path.replace(os.sep, "/"), self.code, self.source)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col + 1}: {self.code} "
                f"{self.message}")


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def _attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._anl_parent = node  # type: ignore[attr-defined]


def _collect_aliases(tree: ast.AST) -> Dict[str, str]:
    """Local name -> fully dotted module path, for every import."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _qual(node: ast.AST, aliases: Dict[str, str]) -> Optional[str]:
    """Dotted path of a Name/Attribute chain with import aliases resolved
    (``jnp.zeros`` -> ``jax.numpy.zeros``); None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    root = aliases.get(node.id, node.id)
    parts.append(root)
    return ".".join(reversed(parts))


def _walk_skipping_defs(node: ast.AST) -> Iterable[ast.AST]:
    """Walk expressions reachable at this node's own execution time —
    nested function/lambda bodies run later, so they are skipped."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            stack.extend(ast.iter_child_nodes(n))


def _contains_attr(node: ast.AST, names: Set[str]) -> bool:
    return any(isinstance(n, ast.Attribute) and n.attr in names
               for n in ast.walk(node))


def _tuple_len(node: ast.AST) -> Optional[int]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    return None


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:
        return ast.dump(node)


class _FileLinter:
    def __init__(self, path: str, src: str, tree: ast.Module,
                 importable: bool, select: Optional[Set[str]]):
        self.path = path
        self.src_lines = src.splitlines()
        self.tree = tree
        self.importable = importable
        self.select = select
        self.aliases = _collect_aliases(tree)
        self.findings: List[Finding] = []
        self.defs_by_name: Dict[str, List[ast.FunctionDef]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs_by_name.setdefault(node.name, []).append(node)

    # -- plumbing -----------------------------------------------------------

    def qual(self, node: ast.AST) -> Optional[str]:
        return _qual(node, self.aliases)

    def report(self, node: ast.AST, code: str, message: str) -> None:
        if self.select and code not in self.select:
            return
        line = getattr(node, "lineno", 1)
        src = (self.src_lines[line - 1].strip()
               if 0 < line <= len(self.src_lines) else "")
        m = _NOQA_RE.search(self.src_lines[line - 1]) \
            if 0 < line <= len(self.src_lines) else None
        if m:
            codes = m.group("codes")
            if codes is None or code in {c.strip().upper()
                                         for c in codes.split(",")}:
                return
        f = Finding(self.path, line, getattr(node, "col_offset", 0),
                    code, message, src)
        if f not in self.findings:
            self.findings.append(f)

    def run(self) -> List[Finding]:
        self.anl001()
        self.anl002()
        self.anl003()
        self.anl004()
        self.anl005()
        self.anl006()
        self.findings.sort(key=lambda f: (f.line, f.col, f.code))
        return self.findings

    def _pallas_call_sites(self) -> List[ast.Call]:
        sites = []
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                q = self.qual(node.func)
                if q is not None and q.endswith("pallas_call") \
                        and "pallas" in q:
                    sites.append(node)
        return sites

    # -- ANL001: import-time device-array construction ----------------------

    def anl001(self) -> None:
        if not self.importable:
            return
        # statements executed at import: module body, plus conditional /
        # class bodies at module level (functions run later)
        stack: List[ast.AST] = [self.tree]
        while stack:
            scope = stack.pop()
            for stmt in getattr(scope, "body", []) + \
                    getattr(scope, "orelse", []) + \
                    getattr(scope, "finalbody", []):
                if isinstance(stmt, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    continue
                if isinstance(stmt, (ast.If, ast.Try, ast.With,
                                     ast.ClassDef, ast.For, ast.While)):
                    stack.append(stmt)
                    continue
                for node in _walk_skipping_defs(stmt):
                    if isinstance(node, ast.Call):
                        self._check_import_time_call(node)
        for handler in [n for n in ast.walk(self.tree)
                        if isinstance(n, ast.ExceptHandler)
                        and self._at_module_level(n)]:
            for stmt in handler.body:
                for node in _walk_skipping_defs(stmt):
                    if isinstance(node, ast.Call):
                        self._check_import_time_call(node)

    def _at_module_level(self, node: ast.AST) -> bool:
        p = getattr(node, "_anl_parent", None)
        while p is not None:
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                return False
            p = getattr(p, "_anl_parent", None)
        return True

    def _check_import_time_call(self, node: ast.Call) -> None:
        q = self.qual(node.func)
        if q is None:
            return
        hit = (
            (q.startswith("jax.numpy.")
             and q.rsplit(".", 1)[1] in _ARRAY_CTORS)
            or q.startswith("jax.random.")
            or q in _BACKEND_CALLS
        )
        if hit:
            self.report(
                node, "ANL001",
                f"`{_unparse(node.func)}(...)` at import time builds a "
                f"device array / commits a backend before "
                f"jax.distributed.initialize can run; build it lazily or "
                f"use numpy for module constants")

    # -- ANL002: host syncs in traced contexts and hot loops ----------------

    def _jit_contexts(self) -> List[ast.AST]:
        ctxs: List[ast.AST] = []
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if self._has_jit_decorator(node):
                    ctxs.append(node)
                    continue
                # a step function defined inside a make_* factory
                p = getattr(node, "_anl_parent", None)
                while p is not None:
                    if isinstance(p, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)) \
                            and p.name.startswith("make_"):
                        ctxs.append(node)
                        break
                    p = getattr(p, "_anl_parent", None)
            elif isinstance(node, ast.Call):
                q = self.qual(node.func)
                if q in _TRACER_CONSUMERS:
                    for arg in node.args:
                        if isinstance(arg, ast.Lambda):
                            ctxs.append(arg)
                        elif isinstance(arg, ast.Name):
                            ctxs.extend(self.defs_by_name.get(arg.id, []))
        return ctxs

    def _has_jit_decorator(self, node) -> bool:
        for dec in node.decorator_list:
            q = self.qual(dec) if not isinstance(dec, ast.Call) \
                else self.qual(dec.func)
            if q in ("jax.jit", "jax.pmap", "jax.vmap"):
                return True
            if isinstance(dec, ast.Call) \
                    and q in ("functools.partial", "partial") and dec.args:
                inner = self.qual(dec.args[0])
                if inner in ("jax.jit", "jax.pmap", "jax.vmap"):
                    return True
        return False

    def _jitted_callable_names(self) -> Tuple[Set[str], Set[str]]:
        """Names / attribute names statically bound to ``jax.jit(...)``."""
        names: Set[str] = set()
        attrs: Set[str] = set()
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Assign) and isinstance(node.value,
                                                           ast.Call):
                q = self.qual(node.value.func)
                if q in ("jax.jit", "jax.pmap"):
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            names.add(tgt.id)
                        elif isinstance(tgt, ast.Attribute):
                            attrs.add(tgt.attr)
        return names, attrs

    def _sync_call_kind(self, node: ast.Call,
                        hot_loop: bool) -> Optional[str]:
        q = self.qual(node.func)
        if q in _SYNC_CALLS:
            return _unparse(node.func)
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "item" and not node.args:
            return ".item()"
        if isinstance(node.func, ast.Name) \
                and node.func.id in ("float", "int", "bool") \
                and len(node.args) == 1:
            if hot_loop and node.func.id != "float":
                return None          # int()/bool() too noisy on host loops
            arg = node.args[0]
            # bare names, attributes, literals and arithmetic are far
            # more often static scalars (shapes, config) than device
            # values; only a Call or Subscript argument reliably smells
            # like an array being pulled to host
            if isinstance(arg, (ast.Call, ast.Subscript)):
                if _contains_attr(arg, {"shape", "ndim", "size", "dtype"}):
                    return None      # static shape arithmetic is fine
                if isinstance(arg, ast.Call) \
                        and isinstance(arg.func, ast.Name) \
                        and arg.func.id == "len":
                    return None
                return f"{node.func.id}()"
        return None

    def anl002(self) -> None:
        seen: Set[int] = set()
        for ctx in self._jit_contexts():
            if id(ctx) in seen:
                continue
            seen.add(id(ctx))
            body = ctx.body if isinstance(ctx.body, list) else [ctx.body]
            for stmt in body:
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Call):
                        kind = self._sync_call_kind(node, hot_loop=False)
                        if kind:
                            name = getattr(ctx, "name", "<lambda>")
                            self.report(
                                node, "ANL002",
                                f"`{kind}` forces a host-device sync "
                                f"inside traced context `{name}` — it "
                                f"fails under jit and devalues the "
                                f"compiled hot path; keep values on "
                                f"device or move the fetch outside the "
                                f"traced step")
        # hot loops: a loop that drives a jitted step and materializes
        # per iteration
        jit_names, jit_attrs = self._jitted_callable_names()
        step_attrs = jit_attrs | {"decode", "prefill"}
        for loop in [n for n in ast.walk(self.tree)
                     if isinstance(n, (ast.For, ast.While))]:
            body_nodes = [n for stmt in loop.body
                          for n in _walk_skipping_defs(stmt)] + loop.body
            calls = [n for n in body_nodes if isinstance(n, ast.Call)]
            if any(isinstance(c.func, ast.Attribute)
                   and c.func.attr == "block_until_ready" for c in calls):
                continue             # explicit timing loop
            drives_jit = any(
                (isinstance(c.func, ast.Attribute)
                 and (c.func.attr in step_attrs
                      or c.func.attr.startswith("_jit")))
                or (isinstance(c.func, ast.Name)
                    and (c.func.id in jit_names
                         or c.func.id.startswith("jit_")))
                for c in calls)
            if not drives_jit:
                continue
            for c in calls:
                kind = self._sync_call_kind(c, hot_loop=True)
                if kind:
                    self.report(
                        c, "ANL002",
                        f"`{kind}` materializes device values on every "
                        f"iteration of a loop driving a jitted step — "
                        f"fetch once per window (stack on device, one "
                        f"np.asarray/device_get at the boundary)")

    # -- ANL003: pallas_call structure --------------------------------------

    def _block_spec_parts(self, call: ast.Call):
        """(block_shape_tuple, index_map_lambda) of a BlockSpec call."""
        shape = imap = None
        args = list(call.args)
        if args and isinstance(args[0], (ast.Tuple, ast.List)):
            shape = args[0]
        if len(args) > 1 and isinstance(args[1], ast.Lambda):
            imap = args[1]
        for kw in call.keywords:
            if kw.arg == "block_shape" \
                    and isinstance(kw.value, (ast.Tuple, ast.List)):
                shape = kw.value
            if kw.arg == "index_map" and isinstance(kw.value, ast.Lambda):
                imap = kw.value
        return shape, imap

    def anl003(self) -> None:
        for node in self._pallas_call_sites():
            kw = {k.arg: k.value for k in node.keywords if k.arg}
            grid_n = _tuple_len(kw.get("grid")) if "grid" in kw else None
            specs: List[Tuple[ast.Call, str]] = []
            in_specs = kw.get("in_specs")
            n_in_specs = None
            if isinstance(in_specs, (ast.List, ast.Tuple)):
                n_in_specs = len(in_specs.elts)
                specs += [(e, "in_specs") for e in in_specs.elts
                          if isinstance(e, ast.Call)]
            out_specs = kw.get("out_specs")
            if isinstance(out_specs, ast.Call):
                specs.append((out_specs, "out_specs"))
            elif isinstance(out_specs, (ast.List, ast.Tuple)):
                specs += [(e, "out_specs") for e in out_specs.elts
                          if isinstance(e, ast.Call)]

            block_dim_exprs: Set[str] = set()
            out_block_rank = None
            for spec, role in specs:
                shape, imap = self._block_spec_parts(spec)
                if shape is not None:
                    block_dim_exprs |= {_unparse(d) for d in shape.elts}
                if imap is not None and grid_n is not None:
                    # defaulted params are the closure-capture idiom
                    # (lambda b, h, i, j, qpk=qpk: ...), not grid indices
                    arity = (len(imap.args.args)
                             - len(imap.args.defaults))
                    if arity != grid_n:
                        self.report(
                            spec, "ANL003",
                            f"{role} index_map takes {arity} grid "
                            f"indices but the grid has {grid_n} "
                            f"dimensions")
                if imap is not None and shape is not None:
                    ret_n = _tuple_len(imap.body)
                    if ret_n is not None and ret_n != len(shape.elts):
                        self.report(
                            spec, "ANL003",
                            f"{role} index_map returns {ret_n} block "
                            f"indices for a rank-{len(shape.elts)} "
                            f"block shape")
                if role == "out_specs" and shape is not None:
                    out_block_rank = len(shape.elts)

            out_shape = kw.get("out_shape")
            if isinstance(out_shape, ast.Call) \
                    and (self.qual(out_shape.func) or "").endswith(
                        "ShapeDtypeStruct") \
                    and out_shape.args:
                rank = _tuple_len(out_shape.args[0])
                if rank is not None and out_block_rank is not None \
                        and rank != out_block_rank:
                    self.report(
                        out_shape, "ANL003",
                        f"out_specs block shape is rank {out_block_rank} "
                        f"but out_shape is rank {rank}")

            parent = getattr(node, "_anl_parent", None)
            if isinstance(parent, ast.Call) and parent.func is node \
                    and n_in_specs is not None \
                    and not any(isinstance(a, ast.Starred)
                                for a in parent.args) \
                    and len(parent.args) != n_in_specs:
                self.report(
                    parent, "ANL003",
                    f"pallas_call declares {n_in_specs} in_specs but is "
                    f"applied to {len(parent.args)} operands")

            scratch = kw.get("scratch_shapes")
            if isinstance(scratch, (ast.List, ast.Tuple)) \
                    and block_dim_exprs:
                for entry in scratch.elts:
                    if not (isinstance(entry, ast.Call) and entry.args
                            and isinstance(entry.args[0],
                                           (ast.Tuple, ast.List))):
                        continue
                    sq = self.qual(entry.func) or ""
                    if not sq.endswith((".VMEM", ".SMEM")):
                        continue
                    for dim in entry.args[0].elts:
                        du = _unparse(dim)
                        if du in block_dim_exprs:
                            continue
                        if isinstance(dim, ast.Constant) \
                                and dim.value == 1:
                            continue
                        self.report(
                            entry, "ANL003",
                            f"scratch dim `{du}` is not drawn from any "
                            f"BlockSpec block shape — scratch tiles must "
                            f"stay consistent with the block tiling")

            interp = kw.get("interpret")
            if interp is not None and not isinstance(
                    interp, (ast.Constant, ast.Name, ast.Attribute)):
                bad = isinstance(interp, ast.Call) or any(
                    (q2 := _qual(n2, self.aliases)) is not None
                    and q2.startswith(("jax.", "jax.numpy."))
                    for n2 in ast.walk(interp)
                    if isinstance(n2, (ast.Name, ast.Attribute)))
                if bad:
                    self.report(
                        interp, "ANL003",
                        "interpret= must be a Python bool, never a "
                        "computed/traced value — a traced flag makes the "
                        "kernel retrace per call")
            if isinstance(interp, ast.Constant) \
                    and not isinstance(interp.value, bool):
                self.report(interp, "ANL003",
                            "interpret= must be a Python bool")

    # -- ANL004: custom_vjp declarations ------------------------------------

    def _custom_vjp_decoration(self, node):
        """(is_custom_vjp, nondiff_tuple_or_None) for a FunctionDef."""
        for dec in node.decorator_list:
            if self.qual(dec) == "jax.custom_vjp":
                return True, ()
            if isinstance(dec, ast.Call):
                q = self.qual(dec.func)
                if q == "jax.custom_vjp":
                    nd = self._nondiff_from_kw(dec.keywords)
                    return True, nd
                if q in ("functools.partial", "partial") and dec.args \
                        and self.qual(dec.args[0]) == "jax.custom_vjp":
                    nd = self._nondiff_from_kw(dec.keywords)
                    return True, nd
        return False, None

    @staticmethod
    def _nondiff_from_kw(keywords):
        for kw in keywords:
            if kw.arg == "nondiff_argnums":
                if isinstance(kw.value, (ast.Tuple, ast.List)):
                    vals = [e.value for e in kw.value.elts
                            if isinstance(e, ast.Constant)]
                    return tuple(vals)
                if isinstance(kw.value, ast.Constant):
                    return (kw.value.value,)
                return None          # dynamic — can't check
        return ()

    def anl004(self) -> None:
        defvjp_names = {
            n.func.value.id
            for n in ast.walk(self.tree)
            if isinstance(n, ast.Call)
            and isinstance(n.func, ast.Attribute)
            and n.func.attr == "defvjp"
            and isinstance(n.func.value, ast.Name)}
        for node in ast.walk(self.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            is_cvjp, nondiff = self._custom_vjp_decoration(node)
            if not is_cvjp:
                continue
            pos = list(node.args.posonlyargs) + list(node.args.args)
            if nondiff is not None:
                for idx in nondiff:
                    if isinstance(idx, int) and idx >= len(pos):
                        self.report(
                            node, "ANL004",
                            f"nondiff_argnums index {idx} is out of "
                            f"range for `{node.name}` "
                            f"({len(pos)} positional params)")
            declared = set(i for i in (nondiff or ())
                           if isinstance(i, int))
            defaults = node.args.defaults
            offset = len(pos) - len(defaults)
            for i, p in enumerate(pos):
                static = False
                d = defaults[i - offset] if i >= offset else None
                if isinstance(d, ast.Constant) \
                        and isinstance(d.value, (bool, str)):
                    static = True
                ann = p.annotation
                if isinstance(ann, ast.Name) and ann.id in ("bool", "str"):
                    static = True
                if static and i not in declared:
                    self.report(
                        node, "ANL004",
                        f"param `{p.arg}` of custom_vjp `{node.name}` "
                        f"looks static (bool/str) but index {i} is not "
                        f"in nondiff_argnums — it will be traced and "
                        f"break the VJP")
            if node.args.kwonlyargs:
                self.report(
                    node, "ANL004",
                    f"custom_vjp `{node.name}` has keyword-only params — "
                    f"custom_vjp does not support kwargs; make them "
                    f"positional and declare them in nondiff_argnums")
            if node.name not in defvjp_names:
                self.report(
                    node, "ANL004",
                    f"custom_vjp `{node.name}` has no "
                    f"`{node.name}.defvjp(...)` registration in this "
                    f"module — calling its grad will fail at runtime")

    # -- ANL005: scan carry structure ---------------------------------------

    def anl005(self) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            if self.qual(node.func) != "jax.lax.scan" or not node.args:
                continue
            body = node.args[0]
            init_len = (_tuple_len(node.args[1])
                        if len(node.args) > 1 else None)
            if isinstance(body, ast.Lambda):
                ret = body.body
                self._check_scan_return(node, ret, None, init_len,
                                        "<lambda>")
            elif isinstance(body, ast.Name):
                for fn in self.defs_by_name.get(body.id, []):
                    self._check_scan_body(node, fn, init_len)

    def _check_scan_body(self, call, fn, init_len):
        carry_param = None
        params = list(fn.args.posonlyargs) + list(fn.args.args)
        if params:
            carry_param = params[0].arg
        in_len = None
        for stmt in fn.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Tuple) \
                    and isinstance(stmt.value, ast.Name) \
                    and stmt.value.id == carry_param:
                in_len = len(stmt.targets[0].elts)
                break
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.Return) and stmt.value is not None:
                self._check_scan_return(stmt, stmt.value, in_len,
                                        init_len, fn.name)

    def _check_scan_return(self, node, ret, in_len, init_len, name):
        n = _tuple_len(ret)
        if n is not None and n != 2:
            self.report(
                node, "ANL005",
                f"scan body `{name}` returns a {n}-tuple — lax.scan "
                f"bodies must return a (carry, ys) pair")
            return
        out_len = (_tuple_len(ret.elts[0])
                   if isinstance(ret, (ast.Tuple, ast.List)) else None)
        if out_len is None:
            return
        if in_len is not None and out_len != in_len:
            self.report(
                node, "ANL005",
                f"scan body `{name}` unpacks a {in_len}-element carry "
                f"but returns a {out_len}-element carry — the in/out "
                f"carry pytrees must match")
        if init_len is not None and out_len != init_len:
            self.report(
                node, "ANL005",
                f"scan init is a {init_len}-element tuple but body "
                f"`{name}` returns a {out_len}-element carry")

    # -- ANL006: pallas_call with no registered KernelSpec ------------------

    def _has_kernel_spec_registration(self) -> bool:
        # registration in the module itself ...
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                q = self.qual(node.func)
                if q is not None and q.endswith("register_kernel_spec"):
                    return True
        # ... or the shipped layout: a sibling audit.py that registers
        # specs naming this module (only meaningful for real files)
        if not os.path.exists(self.path):
            return False
        sibling = os.path.join(
            os.path.dirname(os.path.abspath(self.path)), "audit.py")
        stem = os.path.splitext(os.path.basename(self.path))[0]
        if not os.path.exists(sibling):
            return False
        try:
            with open(sibling, "r", encoding="utf-8") as fh:
                sib_src = fh.read()
        except OSError:
            return False
        return "register_kernel_spec" in sib_src and stem in sib_src

    def anl006(self) -> None:
        sites = self._pallas_call_sites()
        if not sites or self._has_kernel_spec_registration():
            return
        for node in sites:
            self.report(
                node, "ANL006",
                "pallas_call with no KernelSpec registered for this "
                "module (no register_kernel_spec here or in a sibling "
                "audit.py) — the kernel escapes the static grid/"
                "BlockSpec audit; add a spec (see "
                "repro.analysis.kernel_audit)")


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def lint_source(src: str, path: str = "<string>", *,
                importable: bool = False,
                select: Optional[Iterable[str]] = None) -> List[Finding]:
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, (e.offset or 1) - 1,
                        "ANL000", f"syntax error: {e.msg}")]
    _attach_parents(tree)
    sel = {s.upper() for s in select} if select else None
    return _FileLinter(path, src, tree, importable, sel).run()


def _is_importable(path: str) -> bool:
    return os.path.exists(os.path.join(os.path.dirname(os.path.abspath(
        path)), "__init__.py"))


def lint_file(path: str, *, select: Optional[Iterable[str]] = None,
              importable: Optional[bool] = None) -> List[Finding]:
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    if importable is None:
        importable = _is_importable(path)
    return lint_source(src, path, importable=importable, select=select)


def _iter_py_files(roots: Sequence[str],
                   excludes: Sequence[str]) -> Iterable[str]:
    def excluded(p: str) -> bool:
        norm = p.replace(os.sep, "/")
        return any(x.replace(os.sep, "/") in norm for x in excludes)

    for root in roots:
        if os.path.isfile(root):
            if root.endswith(".py") and not excluded(root):
                yield root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames
                                 if d != "__pycache__")
            for fn in sorted(filenames):
                p = os.path.join(dirpath, fn)
                if fn.endswith(".py") and not excluded(p):
                    yield p


def lint_paths(roots: Sequence[str], *,
               select: Optional[Iterable[str]] = None,
               excludes: Sequence[str] = DEFAULT_EXCLUDES
               ) -> List[Finding]:
    findings: List[Finding] = []
    for path in _iter_py_files(roots, excludes):
        findings.extend(lint_file(path, select=select))
    return findings


# -- baseline ----------------------------------------------------------------

def format_baseline_entry(f: Finding) -> str:
    path, code, src = f.baseline_key()
    return f"{path}|{code}|{src}"


def load_baseline(path: str) -> Counter:
    entries: Counter = Counter()
    if not os.path.exists(path):
        return entries
    with open(path, "r", encoding="utf-8") as fh:
        for raw in fh:
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split("|", 2)
            if len(parts) == 3:
                entries[(parts[0], parts[1], parts[2])] += 1
    return entries


def apply_baseline(findings: List[Finding],
                   baseline: Counter) -> Tuple[List[Finding], List[Finding]]:
    """-> (new findings, baselined findings). Each baseline entry absorbs
    as many findings as it has copies."""
    budget = Counter(baseline)
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        k = f.baseline_key()
        if budget[k] > 0:
            budget[k] -= 1
            old.append(f)
        else:
            new.append(f)
    return new, old


def stale_baseline_entries(findings: List[Finding], baseline: Counter,
                           select: Optional[Iterable[str]] = None
                           ) -> List[Tuple[str, str, str]]:
    """Baseline entries (with multiplicity) that absorbed no finding —
    the suppression has rotted and must be deleted. Under a narrowed
    ``select``, entries for codes that were not run are not stale."""
    budget = Counter(baseline)
    for f in findings:
        k = f.baseline_key()
        if budget[k] > 0:
            budget[k] -= 1
    sel = {s.upper() for s in select} if select else None
    stale: List[Tuple[str, str, str]] = []
    for key, count in sorted(budget.items()):
        if sel is not None and key[1] not in sel:
            continue
        stale.extend([key] * count)
    return stale


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Repo-specific JAX/Pallas lint pass (rules "
                    "ANL001..ANL006; see module docstring).")
    ap.add_argument("paths", nargs="+",
                    help="files or directory roots to lint")
    ap.add_argument("--check", action="store_true",
                    help="CI mode: terse output, exit 1 on any finding "
                         "not covered by the baseline")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes to run "
                         "(default: all)")
    ap.add_argument("--baseline", default="analysis_baseline.txt",
                    help="baseline file of accepted findings "
                         "(default: analysis_baseline.txt)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write all current findings to the baseline "
                         "file and exit 0")
    ap.add_argument("--no-default-excludes", action="store_true",
                    help="also lint the linter's own positive fixtures "
                         f"(default excludes: {DEFAULT_EXCLUDES})")
    args = ap.parse_args(argv)

    select = (args.select.split(",") if args.select else None)
    excludes = () if args.no_default_excludes else DEFAULT_EXCLUDES
    findings = lint_paths(args.paths, select=select, excludes=excludes)

    if args.write_baseline:
        with open(args.baseline, "w", encoding="utf-8") as fh:
            fh.write("# repro.analysis.lint baseline — accepted findings"
                     "\n# format: path|rule|stripped source line\n"
                     "# add a trailing '# why: ...' comment line above "
                     "each entry to justify it\n")
            for f in findings:
                fh.write(format_baseline_entry(f) + "\n")
        print(f"wrote {len(findings)} baseline entrie(s) to "
              f"{args.baseline}")
        return 0

    baseline = (Counter() if args.no_baseline
                else load_baseline(args.baseline))
    new, old = apply_baseline(findings, baseline)
    stale = stale_baseline_entries(findings, baseline, select)

    if not args.check:
        for f in new:
            print(f.render())
        for f in old:
            print(f"{f.render()}  [baselined]")
    elif new:
        for f in new:
            print(f.render())
    for path, code, src in stale:
        print(f"{args.baseline}: stale entry matches no finding — "
              f"delete it: {path}|{code}|{src}")
    counts = Counter(f.code for f in new)
    summary = ", ".join(f"{c}: {n}" for c, n in sorted(counts.items()))
    if new or (stale and args.check):
        if new:
            print(f"{len(new)} finding(s) not in baseline"
                  + (f" ({summary})" if summary else "")
                  + (f"; {len(old)} baselined" if old else ""))
        if stale:
            print(f"{len(stale)} stale baseline entrie(s)")
        return 1
    print(f"clean: 0 new finding(s)"
          + (f", {len(old)} baselined" if old else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
