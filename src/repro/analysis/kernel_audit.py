"""Grid/BlockSpec auditor: prove the tiling invariants of every Pallas
kernel by abstract interpretation — jax-free.

The paper's dataflow claims (OSEL encoding, grouped-core workload
allocation) are only correct if every tile of every operand is touched
exactly where the schedule says: no block reads past an operand edge, no
output tile is left unwritten, and no two grid points race on the same
output tile unless that revisit *is* the declared accumulation. Bitwise
tests pin those invariants for the handful of shapes they run; this
module proves them for a whole shape corpus without compiling anything —
Pallas index maps are pure functions of the grid indices, so the full
grid can be enumerated concretely and every block placement checked with
integer arithmetic.

Kernels self-describe through a :class:`KernelSpec` registry: each
kernel package ships an ``audit.py`` that mirrors its wrapper's tiling
math (via the shared :mod:`repro.kernels.tiling` helpers — the same
functions the wrappers call, so the model cannot drift) and registers
one spec per ``pallas_call`` site. Lint rule ANL006 makes registration
mandatory: a module containing a ``pallas_call`` with no KernelSpec in
its package fails the analysis job.

Per ``pallas_call`` and corpus case, four checks:

bounds        every block origin (``index_map(grid point) * block_shape``,
              Pallas Blocked indexing) plus the block shape stays inside
              the operand, for every grid point, inputs and outputs.
coverage      the union of output block placements covers every output
              tile — no gaps a zero-initialized HBM buffer would silently
              paper over.
disjointness  two distinct grid points may write the same output tile
              only if they differ exclusively in the declared
              accumulation axes, AND their revisits are consecutive in
              grid iteration order (row-major, last axis fastest) — a
              non-consecutive revisit means Mosaic flushes the tile
              mid-reduction and the result silently corrupts in
              non-interpret mode. This is the race class the bitwise
              interpret-mode tests can never see.
vmem          the per-invocation working set (one block per operand +
              scratch) against a configurable budget — the table the
              roofline/bench artifacts cite instead of hand-maintained
              docstring constants.

Run::

    PYTHONPATH=src python -m repro.analysis.kernel_audit [--check]
        [--budget-mib 16] [--kernel SUBSTR] [--json PATH]

``--check`` is the CI gate (exit 1 on any finding); it runs without jax
installed, beside the lint pass in the analysis job.
"""
from __future__ import annotations

import argparse
import importlib
import itertools
import json
import sys
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Operand", "GridCase", "KernelSpec", "AuditFinding", "CaseReport",
    "register_kernel_spec", "get_registry", "load_registry",
    "audit_case", "audit_all", "vmem_table", "DEFAULT_VMEM_BUDGET",
    "main",
]

# Per-core VMEM on current TPU generations is 16 MiB (v4/v5e) to
# 32 MiB (v5p); the audit gates on the conservative end so every kernel
# schedules everywhere.
DEFAULT_VMEM_BUDGET = 16 * 1024 * 1024

# The four kernel families. Each module registers its specs at import.
AUDIT_MODULES = (
    "repro.kernels.flash_attention.audit",
    "repro.kernels.flgw_matmul.audit",
    "repro.kernels.osel_encode.audit",
    "repro.kernels.plan_encode.audit",
)


@dataclass(frozen=True)
class Operand:
    """One pallas_call operand (or result): array shape + BlockSpec."""
    name: str
    shape: Tuple[int, ...]
    block: Tuple[int, ...]
    index_map: Callable[..., Tuple[int, ...]]
    itemsize: int = 4
    role: str = "in"                      # "in" | "out"


@dataclass(frozen=True)
class GridCase:
    """One concrete instantiation of a kernel's grid for a corpus case."""
    label: str
    grid: Tuple[int, ...]
    operands: Tuple[Operand, ...]
    # grid axes allowed to revisit an output tile (reduction axes whose
    # revisits accumulate into VMEM scratch before one final flush)
    accum_axes: frozenset = frozenset()
    scratch_bytes: int = 0
    tags: Tuple[str, ...] = ()            # corpus markers, e.g. "m_gt_4096"


@dataclass(frozen=True)
class KernelSpec:
    """Self-description of one ``pallas_call`` site.

    ``module`` is the dotted module that contains the pallas_call (ANL006
    and the registry-completeness test match on it). ``build`` maps a
    corpus-case param dict to the concrete :class:`GridCase`, mirroring
    the wrapper's tiling math exactly.
    """
    name: str
    module: str
    build: Callable[[dict], GridCase]
    corpus: Tuple[dict, ...]
    note: str = ""


@dataclass(frozen=True)
class AuditFinding:
    kernel: str
    case: str
    check: str                            # bounds|coverage|disjoint|vmem
    message: str

    def render(self) -> str:
        return f"{self.kernel}[{self.case}] {self.check}: {self.message}"


@dataclass
class CaseReport:
    kernel: str
    case: str
    grid: Tuple[int, ...]
    grid_points: int
    vmem_bytes: int
    findings: List[AuditFinding] = field(default_factory=list)
    tags: Tuple[str, ...] = ()

    @property
    def ok(self) -> bool:
        return not self.findings


_REGISTRY: Dict[str, KernelSpec] = {}


def register_kernel_spec(spec: KernelSpec) -> KernelSpec:
    """Register (or re-register, e.g. on module reload) a KernelSpec."""
    _REGISTRY[spec.name] = spec
    return spec


def get_registry() -> Dict[str, KernelSpec]:
    return dict(_REGISTRY)


def load_registry() -> Dict[str, KernelSpec]:
    """Import the audit modules of the four kernel families (jax-free)
    and return the populated registry."""
    for mod in AUDIT_MODULES:
        importlib.import_module(mod)
    return get_registry()


# ---------------------------------------------------------------------------
# the four checks
# ---------------------------------------------------------------------------

def _iter_grid(grid: Tuple[int, ...]) -> Iterable[Tuple[int, ...]]:
    """Row-major grid enumeration — Pallas iteration order (last axis
    fastest), which the disjointness contiguity check relies on."""
    return itertools.product(*(range(n) for n in grid))


def _prod(xs: Iterable[int]) -> int:
    out = 1
    for x in xs:
        out *= x
    return out


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _check_operand(kernel: str, case: GridCase, op: Operand,
                   findings: List[AuditFinding]) -> None:
    grid = case.grid
    ndim = len(op.shape)
    bounds_bad = 0
    bounds_example = ""
    # output bookkeeping: block-index tuple -> (first linear pos,
    # last linear pos, projection of the first writer onto non-accum axes)
    writers: Dict[Tuple[int, ...], Tuple[int, int, Tuple[int, ...]]] = {}
    disjoint_bad = 0
    disjoint_example = ""
    contig_bad = 0
    contig_example = ""
    non_accum = [a for a in range(len(grid)) if a not in case.accum_axes]

    for lin, gp in enumerate(_iter_grid(grid)):
        idx = tuple(op.index_map(*gp))
        if len(idx) != ndim or len(op.block) != ndim:
            findings.append(AuditFinding(
                kernel, case.label, "bounds",
                f"{op.name}: index_map returns {len(idx)} block indices "
                f"for a rank-{ndim} operand (block rank "
                f"{len(op.block)})"))
            return
        origin = tuple(i * b for i, b in zip(idx, op.block))
        if any(o < 0 for o in origin) or any(
                o + b > s for o, b, s in zip(origin, op.block, op.shape)):
            bounds_bad += 1
            if not bounds_example:
                bounds_example = (f"grid point {gp} places block "
                                  f"{op.block} at origin {origin} in "
                                  f"operand shape {op.shape}")
        if op.role != "out":
            continue
        prev = writers.get(idx)
        if prev is None:
            writers[idx] = (lin, lin, tuple(gp[a] for a in non_accum))
            continue
        first, last, proj = prev
        if tuple(gp[a] for a in non_accum) != proj:
            disjoint_bad += 1
            if not disjoint_example:
                axes = [a for a in non_accum
                        if gp[a] != _nth_grid_point(grid, first)[a]]
                disjoint_example = (
                    f"output tile {idx} written by grid points "
                    f"{_nth_grid_point(grid, first)} and {gp}, which "
                    f"differ in undeclared axes {axes} "
                    f"(accum_axes={sorted(case.accum_axes)})")
        elif lin != last + 1:
            contig_bad += 1
            if not contig_example:
                contig_example = (
                    f"output tile {idx} revisited at grid step {lin} "
                    f"after last write at step {last} — revisits must "
                    f"be consecutive in grid order or the accumulator "
                    f"is flushed mid-reduction")
        writers[idx] = (first, lin, proj)

    if bounds_bad:
        findings.append(AuditFinding(
            kernel, case.label, "bounds",
            f"{op.name}: {bounds_bad} grid point(s) out of bounds — "
            f"{bounds_example}"))
    if op.role == "out":
        expected = _prod(_ceil_div(s, b)
                         for s, b in zip(op.shape, op.block))
        if len(writers) < expected:
            missing = expected - len(writers)
            gap = _first_gap(op, writers)
            findings.append(AuditFinding(
                kernel, case.label, "coverage",
                f"{op.name}: {missing} of {expected} output tile(s) "
                f"never written — first gap at block index {gap}"))
        if disjoint_bad:
            findings.append(AuditFinding(
                kernel, case.label, "disjoint",
                f"{op.name}: {disjoint_bad} undeclared overlapping "
                f"write(s) — {disjoint_example}"))
        if contig_bad:
            findings.append(AuditFinding(
                kernel, case.label, "disjoint",
                f"{op.name}: {contig_bad} non-consecutive revisit(s) — "
                f"{contig_example}"))


def _nth_grid_point(grid: Tuple[int, ...], n: int) -> Tuple[int, ...]:
    out = []
    for size in reversed(grid):
        out.append(n % size)
        n //= size
    return tuple(reversed(out))


def _first_gap(op: Operand, writers: Dict) -> Optional[Tuple[int, ...]]:
    tiles = itertools.product(*(range(_ceil_div(s, b))
                                for s, b in zip(op.shape, op.block)))
    for t in tiles:
        if t not in writers:
            return t
    return None


def case_vmem_bytes(case: GridCase) -> int:
    """Per-invocation VMEM working set: one block per operand (in + out)
    plus scratch. Pallas double-buffers pipelined blocks; the budget
    headroom absorbs that (documented, deliberately not modelled — the
    committed number is the schedule's irreducible footprint)."""
    return sum(_prod(op.block) * op.itemsize
               for op in case.operands) + case.scratch_bytes


def audit_case(kernel: str, case: GridCase, *,
               budget: int = DEFAULT_VMEM_BUDGET) -> CaseReport:
    findings: List[AuditFinding] = []
    for op in case.operands:
        _check_operand(kernel, case, op, findings)
    vmem = case_vmem_bytes(case)
    if vmem > budget:
        findings.append(AuditFinding(
            kernel, case.label, "vmem",
            f"working set {vmem} B ({vmem / 2**20:.2f} MiB) exceeds the "
            f"{budget / 2**20:.1f} MiB budget"))
    return CaseReport(kernel, case.label, case.grid,
                      _prod(case.grid), vmem, findings, case.tags)


def audit_all(*, budget: int = DEFAULT_VMEM_BUDGET,
              kernel_filter: str = "") -> List[CaseReport]:
    reports: List[CaseReport] = []
    registry = load_registry()
    for name in sorted(registry):
        if kernel_filter and kernel_filter not in name:
            continue
        spec = registry[name]
        for params in spec.corpus:
            case = spec.build(dict(params))
            reports.append(audit_case(name, case, budget=budget))
    return reports


def vmem_table(*, budget: int = DEFAULT_VMEM_BUDGET) -> Dict[str, Dict]:
    """{kernel: {case: {vmem_bytes, grid, grid_points, ok}}} — the
    machine-readable table the roofline/bench artifacts consume."""
    table: Dict[str, Dict] = {}
    for r in audit_all(budget=budget):
        table.setdefault(r.kernel, {})[r.case] = {
            "vmem_bytes": r.vmem_bytes,
            "grid": list(r.grid),
            "grid_points": r.grid_points,
            "ok": r.ok,
        }
    return table


def corpus_tags() -> set:
    """Union of corpus tags across all registered cases (acceptance
    checks assert 'm_gt_4096' and 'slack_gt_1' are present)."""
    tags: set = set()
    for r in audit_all():
        tags.update(r.tags)
    return tags


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _fmt_bytes(n: int) -> str:
    if n >= 2**20:
        return f"{n / 2**20:.2f}MiB"
    return f"{n / 2**10:.1f}KiB"


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.kernel_audit",
        description="Prove grid/BlockSpec invariants (bounds, coverage, "
                    "write-disjointness, VMEM budget) for every "
                    "registered Pallas kernel — no jax needed.")
    ap.add_argument("--check", action="store_true",
                    help="CI mode: terse table, exit 1 on any finding")
    ap.add_argument("--budget-mib", type=float, default=None,
                    help="VMEM working-set budget in MiB "
                         f"(default {DEFAULT_VMEM_BUDGET / 2**20:.0f})")
    ap.add_argument("--kernel", default="",
                    help="only audit kernels whose name contains this")
    ap.add_argument("--json", default=None,
                    help="also dump the per-case table as JSON")
    args = ap.parse_args(argv)

    budget = (int(args.budget_mib * 2**20) if args.budget_mib
              else DEFAULT_VMEM_BUDGET)
    reports = audit_all(budget=budget, kernel_filter=args.kernel)
    if not reports:
        print("no KernelSpecs matched", file=sys.stderr)
        return 1

    width = max(len(r.kernel) for r in reports)
    cwidth = max(len(r.case) for r in reports)
    print(f"{'kernel':<{width}}  {'case':<{cwidth}}  "
          f"{'grid':<18} {'points':>7}  {'vmem':>9}  checks")
    for r in reports:
        status = "ok" if r.ok else ",".join(
            sorted({f.check for f in r.findings}))
        print(f"{r.kernel:<{width}}  {r.case:<{cwidth}}  "
              f"{str(r.grid):<18} {r.grid_points:>7}  "
              f"{_fmt_bytes(r.vmem_bytes):>9}  {status}")
    findings = [f for r in reports for f in r.findings]
    tags = {t for r in reports for t in r.tags}
    print(f"{len(reports)} case(s) across "
          f"{len({r.kernel for r in reports})} kernel(s); corpus tags: "
          f"{', '.join(sorted(tags)) or '-'}")

    if args.json:
        doc = {r.kernel: {} for r in reports}
        for r in reports:
            doc[r.kernel][r.case] = {
                "grid": list(r.grid), "grid_points": r.grid_points,
                "vmem_bytes": r.vmem_bytes, "ok": r.ok,
                "findings": [f.render() for f in r.findings],
            }
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump(doc, fh, indent=1)
        print(f"wrote {args.json}")

    if findings:
        for f in findings:
            print(f.render())
        print(f"{len(findings)} finding(s)")
        return 1
    print("audit clean: bounds, coverage, disjointness and VMEM hold "
          "for every registered kernel across the corpus")
    return 0


if __name__ == "__main__":
    # Under ``python -m`` this module is ``__main__``; the audit modules
    # register into the canonical ``repro.analysis.kernel_audit`` copy,
    # so delegate there rather than audit an empty registry.
    from repro.analysis.kernel_audit import main as _canonical_main
    sys.exit(_canonical_main())
