"""MARL workload: IC3Net, the env registry, the on-device trainer."""
