"""IC3Net (Singh et al., '19) — the MARL network LearningGroup trains.

Per agent (weights shared across agents): an observation encoder, an LSTM
whose input is the encoded observation plus a gated mean of the other
agents' communication vectors, a discrete-action policy head, a value head,
and a communication gate head (the "learning when to communicate" part).

Every projection is a FLGW-capable ``dense`` layer — this network is where
the paper applies weight grouping (Fig. 4a/9): encoder, the 4H LSTM gate
matrices, the communication projection and the output heads all carry IG/OG
grouping matrices when ``flgw_groups > 1``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import encoder
from repro.core.flgw import FLGWConfig
from repro.models.layers import dense_init, plan_of, proj
from repro.sharding.partition import constrain


@dataclasses.dataclass(frozen=True)
class IC3NetConfig:
    hidden: int = 128
    n_agents: int = 3
    n_actions: int = 5
    obs_dim: int = 0              # filled from the env at init time
    flgw_groups: int = 1
    flgw_path: str = "masked"
    comm_detach: bool = True      # IC3Net detaches comm grads across agents

    @property
    def flgw(self) -> FLGWConfig | None:
        if self.flgw_groups <= 1:
            return None
        return FLGWConfig(groups=self.flgw_groups, path=self.flgw_path)


def init(key: jax.Array, cfg: IC3NetConfig):
    h = cfg.hidden
    ks = jax.random.split(key, 8)
    fl = cfg.flgw
    params, specs = {}, {}
    params["enc"], specs["enc"] = dense_init(
        ks[0], cfg.obs_dim, h, flgw=fl, axes=("in", "hidden"),
        dtype=jnp.float32)
    # LSTM: x (h) and hidden (h) -> 4 gates
    params["lstm_x"], specs["lstm_x"] = dense_init(
        ks[1], h, 4 * h, flgw=fl, axes=("hidden", "gates"),
        dtype=jnp.float32)
    params["lstm_h"], specs["lstm_h"] = dense_init(
        ks[2], h, 4 * h, flgw=fl, axes=("hidden", "gates"),
        dtype=jnp.float32)
    params["lstm_b"] = jnp.zeros((4 * h,), jnp.float32)
    specs["lstm_b"] = (None,)
    params["comm"], specs["comm"] = dense_init(
        ks[3], h, h, flgw=fl, axes=("hidden", "hidden"), dtype=jnp.float32)
    params["policy"], specs["policy"] = dense_init(
        ks[4], h, cfg.n_actions, flgw=fl, axes=("hidden", "out"),
        dtype=jnp.float32)
    params["value"], specs["value"] = dense_init(
        ks[5], h, 1, flgw=None, axes=("hidden", "out"), dtype=jnp.float32)
    params["gate"], specs["gate"] = dense_init(
        ks[6], h, 2, flgw=None, axes=("hidden", "out"), dtype=jnp.float32)
    return params, specs


def encode_plans(params, cfg: IC3NetConfig) -> encoder.PlanState:
    """One OSEL-analogue pass: the PlanState of every FLGW layer.

    Returns the empty PlanState unless the compact ``grouped`` path is
    active — the masked/dense paths never consume plans, and the empty
    state keeps the training-loop carry structure uniform across
    configurations.
    """
    fl = cfg.flgw
    if fl is None or fl.path != "grouped":
        return encoder.empty_state()
    return encoder.encode_plans(params, fl)


def flops_per_step(cfg: IC3NetConfig) -> float:
    """Dense-equivalent FLOPs of one forward ``policy_step`` (all agents).

    The same accounting the paper's Fig. 11 uses: 2·M·N per projection,
    summed over encoder, the two 4H LSTM gate matrices, the communication
    projection and the three heads.
    """
    h = cfg.hidden
    per_agent = 2 * (cfg.obs_dim * h          # encoder
                     + h * 4 * h * 2          # LSTM x/h gates
                     + h * h                  # comm projection
                     + h * cfg.n_actions + h + h * 2)  # policy/value/gate
    return float(cfg.n_agents * per_agent)


def lstm_cell(params, cfg: IC3NetConfig, x, hc, plans=None):
    h, c = hc
    fl = cfg.flgw
    gates = proj(params["lstm_x"], x, fl, plan=plan_of(plans, "lstm_x")) \
        + proj(params["lstm_h"], h, fl, plan=plan_of(plans, "lstm_h")) \
        + params["lstm_b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h, c


def policy_step(params, cfg: IC3NetConfig, obs, hc, gate_prev, plans=None):
    """One communication+action step for all agents of one env.

    obs: (A, obs_dim); hc: ((A,H),(A,H)); gate_prev: (A,) float in [0,1] —
    the previous step's communication gate decision per agent.
    ``plans``: cached sparse metadata from :func:`encode_plans` (grouped
    path); ``None``/``{}`` re-encodes inside each projection.
    Returns (action_logits (A,n_act), value (A,), gate_logits (A,2), new_hc).
    """
    a = cfg.n_agents
    fl = cfg.flgw
    h, c = hc
    # Mesh path: per-agent work shards over the "agent" axis (no-op hints
    # off the mesh — see repro.sharding.partition.constrain). The gated
    # mean below is the one cross-agent reduction: on an agent-sharded
    # mesh it is the communication all-reduce, everything else is local.
    obs = constrain(obs, ("agent", None))
    comm_src = jax.lax.stop_gradient(h) if cfg.comm_detach else h
    cvec = proj(params["comm"], comm_src, fl,
                plan=plan_of(plans, "comm"))             # (A, H)
    cvec = cvec * gate_prev[:, None]
    # gated mean over the *other* agents
    total = jnp.sum(cvec, axis=0, keepdims=True)
    denom = max(a - 1, 1)
    comm_in = (total - cvec) / denom                      # (A, H)
    e = jnp.tanh(proj(params["enc"], obs, fl, plan=plan_of(plans, "enc")))
    x = constrain(e + comm_in, ("agent", None))
    h, c = lstm_cell(params, cfg, x, (h, c), plans)
    h = constrain(h, ("agent", None))
    logits = proj(params["policy"], h, fl, plan=plan_of(plans, "policy"))
    value = proj(params["value"], h)[:, 0]
    gate_logits = proj(params["gate"], h)
    return logits, value, gate_logits, (h, c)


def initial_state(cfg: IC3NetConfig):
    z = jnp.zeros((cfg.n_agents, cfg.hidden), jnp.float32)
    return (z, z), jnp.ones((cfg.n_agents,), jnp.float32)
