"""MARL training loop: batched Predator-Prey rollouts + REINFORCE/A2C.

Reproduces the paper's algorithm-validation setup (§IV-A): IC3Net on
Predator-Prey, RMSprop lr=1e-3, minibatch of B parallel environments per
iteration, success rate (% episodes where all predators reach the prey)
as the accuracy metric. FLGW sparsity is controlled by the IC3NetConfig.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.marl import env as env_mod
from repro.marl import ic3net
from repro.optim.optimizers import rmsprop


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    batch: int = 16               # parallel envs (paper: B ∈ 1..32)
    lr: float = 1e-3              # paper: RMSprop 0.001
    gamma: float = 0.99
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    gate_coef: float = 0.01       # IC3Net gate regularizer


def rollout(params, key, cfg: ic3net.IC3NetConfig, ecfg: env_mod.EnvConfig):
    """One full episode for one env. Returns per-step tensors + success."""
    k_env, k_act = jax.random.split(key)
    state = env_mod.reset(k_env, ecfg)
    hc, gate = ic3net.initial_state(cfg)

    def step_fn(carry, k):
        state, hc, gate, done = carry
        obs = env_mod.observe(state, ecfg)
        logits, value, gate_logits, hc = ic3net.policy_step(
            params, cfg, obs, hc, gate)
        action = jax.random.categorical(k, logits)              # (A,)
        logp = jax.nn.log_softmax(logits)
        logp_a = jnp.take_along_axis(logp, action[:, None], 1)[:, 0]
        entropy = -jnp.sum(jax.nn.softmax(logits) * logp, axis=-1)
        kg, _ = jax.random.split(k)
        new_gate = jax.random.bernoulli(
            kg, jax.nn.softmax(gate_logits)[:, 1]).astype(jnp.float32)
        nstate, reward, ndone = env_mod.step(state, action, ecfg)
        # freeze transitions after done
        reward = jnp.where(done, 0.0, reward)
        nstate = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), state, nstate)
        out = (reward, logp_a, value, entropy,
               jax.nn.log_softmax(gate_logits)[:, 1] * new_gate, new_gate)
        return (nstate, hc, new_gate, done | ndone), out

    keys = jax.random.split(k_act, ecfg.max_steps)
    (state, _, _, _), (rew, logp, val, ent, gate_logp, gates) = \
        jax.lax.scan(step_fn, (state, hc, gate,
                               jnp.zeros((), bool)), keys)
    return rew, logp, val, ent, gate_logp, gates, env_mod.success(state)


def a2c_loss(params, key, cfg, ecfg, tcfg: TrainConfig):
    keys = jax.random.split(key, tcfg.batch)
    rew, logp, val, ent, gate_logp, gates, succ = jax.vmap(
        lambda k: rollout(params, k, cfg, ecfg))(keys)
    # returns-to-go, (B, T, A)
    def disc(carry, r):
        carry = r + tcfg.gamma * carry
        return carry, carry
    _, returns = jax.lax.scan(disc, jnp.zeros_like(rew[:, 0]),
                              rew[:, ::-1].swapaxes(0, 1))
    returns = returns[::-1].swapaxes(0, 1)                    # (B, T, A)
    adv = returns - val
    pg = -jnp.mean(logp * jax.lax.stop_gradient(adv))
    vloss = jnp.mean(adv ** 2)
    eloss = -jnp.mean(ent)
    gloss = jnp.mean(gates)                                   # talk less
    loss = pg + tcfg.value_coef * vloss + tcfg.entropy_coef * eloss \
        + tcfg.gate_coef * gloss
    return loss, {"success": jnp.mean(succ.astype(jnp.float32)),
                  "return": jnp.mean(jnp.sum(rew, axis=1)),
                  "loss": loss}


@partial(jax.jit, static_argnames=("cfg", "ecfg", "tcfg"))
def train_step(params, opt_state, key, cfg, ecfg, tcfg: TrainConfig):
    (loss, metrics), grads = jax.value_and_grad(
        a2c_loss, has_aux=True)(params, key, cfg, ecfg, tcfg)
    params, opt_state = rmsprop(params, grads, opt_state, lr=tcfg.lr)
    return params, opt_state, metrics


def train(cfg: ic3net.IC3NetConfig, ecfg: env_mod.EnvConfig,
          tcfg: TrainConfig, iterations: int, seed: int = 0,
          log_every: int = 0):
    cfg = dataclasses.replace(cfg, obs_dim=env_mod.obs_dim(ecfg),
                              n_agents=ecfg.n_agents,
                              n_actions=env_mod.N_ACTIONS)
    key = jax.random.PRNGKey(seed)
    kinit, key = jax.random.split(key)
    params, _ = ic3net.init(kinit, cfg)
    opt_state = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                             params)
    history = []
    for it in range(iterations):
        key, k = jax.random.split(key)
        params, opt_state, metrics = train_step(
            params, opt_state, k, cfg, ecfg, tcfg)
        history.append({k2: float(v) for k2, v in metrics.items()})
        if log_every and it % log_every == 0:
            print(f"iter {it:5d} success {history[-1]['success']:.3f} "
                  f"return {history[-1]['return']:.3f}")
    return params, history
