"""On-device multi-scenario MARL training engine (REINFORCE/A2C + FLGW).

Reproduces the paper's algorithm-validation setup (§IV-A) — IC3Net with
RMSprop lr=1e-3, B parallel environments per iteration, success rate as the
accuracy metric — but generalized along the two axes the paper credits for
its speedup and scope:

* **any registered environment** (``repro.marl.envs``): the loop is written
  against the functional ``Env`` protocol, so Predator-Prey, Traffic
  Junction and Spread (and future scenarios) share one engine;
* **fully on device**: iterations run inside a ``jax.lax.scan`` — the host
  never syncs per step. Metrics are accumulated on device and fetched once
  per log window, mirroring the paper's "fully on-chip training" (the FPGA
  never round-trips to a host between iterations). Scale-out runs the same
  scan under ``jit`` on a 2-D ``("env", "agent")`` ``jax.sharding`` mesh
  (``TrainConfig.mesh``; ``repro.launch.mesh.make_marl_mesh``): the rollout
  batch shards over ``env``, per-agent activations over ``agent``, and the
  learner state stays replicated (IC3Net weights are agent-shared). The
  retired ``pmap`` path survives as the deprecated ``TrainConfig.parallel``
  alias, which routes to a 1-D env-only mesh.

A FLGW sparsity schedule (``repro.core.schedule.SparsitySchedule``) threads
through the loop: during ``warmup_steps`` the network trains dense, then the
grouping mask switches on — the G ramp the schedule describes. (G itself is
static: IG/OG shapes depend on it.)
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
import warnings
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import kernels as kernels_mod
from repro.core import encoder, flgw, grouped
from repro.core.schedule import SparsitySchedule
from repro.launch.mesh import make_marl_mesh
from repro.marl import envs as envs_mod
from repro.marl import ic3net
from repro.optim.optimizers import rmsprop, rmsprop_init
from repro.sharding import partition
from repro.sharding.partition import constrain


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    batch: int = 16               # parallel envs (paper: B ∈ 1..32)
    lr: float = 1e-3              # paper: RMSprop 0.001
    gamma: float = 0.99
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    gate_coef: float = 0.01       # IC3Net gate regularizer
    # (env, agent) shard counts of the jax.sharding mesh path; env <= 0
    # auto-fills with whatever devices the agent axis leaves free. None
    # keeps the single-device scan. ``batch`` is the GLOBAL env batch,
    # sharded over the env axis (the retired pmap path rolled out
    # ``batch`` envs per device — multiply by the old device count when
    # migrating).
    mesh: Optional[tuple] = None
    # DEPRECATED: the old pmap data-parallel switch. Routes to a 1-D
    # env-only mesh (mesh=(local_device_count, 1)); set ``mesh`` instead.
    parallel: bool = False


def _policy_terms(logits, gate_logits, action, new_gate):
    """Per-step loss terms from one policy forward + the realised actions.

    Shared by the on-policy :func:`rollout` and the async learner's replay
    (``repro.marl.async_train.replay_terms``): both must derive the exact
    same (logp, entropy, gate_logp) ops from (logits, gate_logits), or the
    decoupled pipeline could never be bitwise-checked against the
    synchronous scan. ``action``/``new_gate`` are the realised (sampled or
    replayed) decisions — integers/0-1 floats, no gradient flows into
    them.
    """
    logp = jax.nn.log_softmax(logits)
    logp_a = jnp.take_along_axis(logp, action[:, None], 1)[:, 0]
    entropy = -jnp.sum(jax.nn.softmax(logits) * logp, axis=-1)
    gate_logp = jax.nn.log_softmax(gate_logits)[:, 1] * new_gate
    return logp_a, entropy, gate_logp


def rollout(params, key, cfg: ic3net.IC3NetConfig, ecfg, env: envs_mod.Env,
            plans=None, collect: bool = False):
    """One full episode for one env. Returns per-step tensors + success.

    ``collect=True`` (the async actor path) additionally returns the raw
    ``(obs, action)`` sequences so a learner process can re-unroll the
    policy over the stored trajectory — the sampled gates already ride the
    default outputs. The default graph is unchanged: the extra stacking
    only exists when requested.
    """
    k_env, k_act = jax.random.split(key)
    state = env.reset(k_env, ecfg)
    hc, gate = ic3net.initial_state(cfg)

    def step_fn(carry, k):
        state, hc, gate, done = carry
        obs = env.observe(state, ecfg)
        logits, value, gate_logits, hc = ic3net.policy_step(
            params, cfg, obs, hc, gate, plans)
        action = jax.random.categorical(k, logits)              # (A,)
        kg, _ = jax.random.split(k)
        new_gate = jax.random.bernoulli(
            kg, jax.nn.softmax(gate_logits)[:, 1]).astype(jnp.float32)
        logp_a, entropy, gate_logp = _policy_terms(
            logits, gate_logits, action, new_gate)
        nstate, reward, ndone = env.step(state, action, ecfg)
        # freeze transitions after done
        reward = jnp.where(done, 0.0, reward)
        nstate = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), state, nstate)
        out = (reward, logp_a, value, entropy, gate_logp, new_gate)
        if collect:
            out = out + (obs, action)
        return (nstate, hc, new_gate, done | ndone), out

    keys = jax.random.split(k_act, ecfg.max_steps)
    (state, _, _, _), outs = jax.lax.scan(
        step_fn, (state, hc, gate, jnp.zeros((), bool)), keys)
    return outs + (env.success(state),)


def a2c_terms(rew, logp, val, ent, gate_logp, gates, succ,
              tcfg: TrainConfig):
    """A2C loss + metrics from per-step trajectory tensors, all (B, T, A).

    The loss core shared by the synchronous path (:func:`a2c_loss`, which
    differentiates through the rollout that produced the tensors) and the
    async learner (``repro.marl.async_train``, which differentiates
    through a replay of a stored trajectory): discounted returns-to-go,
    advantage policy gradient, value regression, entropy and gate
    regularizers. Gradients flow through ``logp``/``val``/``ent``/
    ``gate_logp``; ``rew``/``gates``/``succ`` are data.
    """
    def disc(carry, r):
        carry = r + tcfg.gamma * carry
        return carry, carry
    _, returns = jax.lax.scan(disc, jnp.zeros_like(rew[:, 0]),
                              rew[:, ::-1].swapaxes(0, 1))
    returns = returns[::-1].swapaxes(0, 1)                    # (B, T, A)
    adv = returns - val
    pg = -jnp.mean(logp * jax.lax.stop_gradient(adv))
    vloss = jnp.mean(adv ** 2)
    eloss = -jnp.mean(ent)
    gloss = jnp.mean(gates)                                   # talk less
    loss = pg + tcfg.value_coef * vloss + tcfg.entropy_coef * eloss \
        + tcfg.gate_coef * gloss
    return loss, {"success": jnp.mean(succ.astype(jnp.float32)),
                  "return": jnp.mean(jnp.sum(rew, axis=1)),
                  "loss": loss}


def a2c_loss(params, key, cfg, ecfg, tcfg: TrainConfig, env: envs_mod.Env,
             plans=None):
    keys = jax.random.split(key, tcfg.batch)
    # Mesh path: the rollout batch is the env-axis workload. The logical
    # constraints are inert (no-ops) unless tracing happens under
    # partition.use_constraints(mesh) — single-device runs never pay them.
    keys = constrain(keys, ("env",) + (None,) * (keys.ndim - 1))
    rew, logp, val, ent, gate_logp, gates, succ = jax.vmap(
        lambda k: rollout(params, k, cfg, ecfg, env, plans))(keys)
    rew, logp, val, ent = (constrain(t, ("env", None, "agent"))
                           for t in (rew, logp, val, ent))
    return a2c_terms(rew, logp, val, ent, gate_logp, gates, succ, tcfg)


def _mean_mask_sparsity(params, cfg: ic3net.IC3NetConfig) -> jax.Array:
    """Mean realised mask sparsity over the FLGW layers (0 when dense)."""
    fl = cfg.flgw
    if fl is None:
        return jnp.zeros(())
    vals = [flgw.mask_sparsity(*flgw.grouping_indices(p["ig"], p["og"]),
                               fl.groups)
            for _, p in grouped.iter_flgw_layers(params)]
    return jnp.mean(jnp.stack(vals)) if vals else jnp.zeros(())


def maybe_refresh_plans(params, plans, it, cfg: ic3net.IC3NetConfig,
                        schedule: Optional[SparsitySchedule]):
    """Amortized OSEL refresh — a thin delegate to the one implementation.

    :func:`repro.core.encoder.maybe_refresh` owns the whole policy (fixed
    period, change-driven signature compare, hybrid staleness bound;
    ``lax.cond`` inside, so ``it`` may be a traced int32; empty PlanStates
    pass through untouched). The sync scan carry, the host-loop mirror
    and the async learner loop (``repro.marl.async_train``) all call this
    same delegate — any refresh-behavior divergence between the three
    loops is a bug, pinned by ``test_maybe_refresh_plans_is_pure_delegate``.
    This function adds nothing beyond unwrapping ``cfg.flgw``.
    """
    return encoder.maybe_refresh(params, plans, it, cfg.flgw, schedule)


def _loss_grads(params, key, it, cfg, ecfg, tcfg, env,
                schedule: Optional[SparsitySchedule], plans=None):
    """(metrics, grads) at global iteration ``it`` (traced int32).

    With a schedule, the first ``warmup_steps`` iterations run the dense
    path (mask off) via ``lax.cond`` — both branches share the same param
    tree, so the G ramp happens inside the compiled loop. ``plans`` is the
    cached sparse metadata consumed by the grouped path.
    """
    def vag(c):
        def f(p, k):
            return jax.value_and_grad(a2c_loss, has_aux=True)(
                p, k, c, ecfg, tcfg, env, plans)
        return f

    ramped = (schedule is not None and schedule.warmup_steps > 0
              and cfg.flgw is not None)
    if ramped:
        dense_cfg = dataclasses.replace(cfg, flgw_path="dense")
        (_, metrics), grads = jax.lax.cond(
            schedule.sparse_at(it), vag(cfg), vag(dense_cfg), params, key)
    else:
        (_, metrics), grads = vag(cfg)(params, key)
    metrics = dict(metrics)
    # report the sparsity of the compute that actually ran: 0 on warmup
    # iterations, where the dense branch executed full FLOPs
    sparsity = _mean_mask_sparsity(params, cfg)
    if ramped:
        sparsity = jnp.where(schedule.sparse_at(it), sparsity, 0.0)
    metrics["mask_sparsity"] = sparsity
    return metrics, grads


@partial(jax.jit, static_argnames=("cfg", "ecfg", "tcfg", "env", "schedule"))
def train_step(params, opt_state, key, cfg, ecfg, tcfg: TrainConfig,
               env: envs_mod.Env = None, schedule=None,
               it: jax.Array | int = 0, plans=None):
    """One host-driven update (seed-compatible API; used for parity tests)."""
    env = env or envs_mod.PREDATOR_PREY
    metrics, grads = _loss_grads(params, key, jnp.asarray(it, jnp.int32),
                                 cfg, ecfg, tcfg, env, schedule, plans)
    params, opt_state = rmsprop(params, grads, opt_state, lr=tcfg.lr)
    return params, opt_state, metrics


def _scan_chunk(params, opt_state, key, plans, start, n, cfg, ecfg, tcfg,
                env, schedule):
    """``n`` update iterations as one on-device ``lax.scan``.

    The FLGW plan cache rides in the carry: each iteration first passes
    through ``maybe_refresh_plans`` — a ``lax.cond`` that re-encodes the
    sparse metadata every ``schedule.refresh_every`` steps and reuses the
    carried (stale) plans otherwise, so the grouped Pallas kernel runs
    against amortized metadata inside the compiled loop.

    The same function serves the single-device path (``_train_chunk``) and
    the mesh path (``make_mesh_chunk``): under a mesh, GSPMD partitions the
    rollout from the logical constraints in ``a2c_loss`` /
    ``ic3net.policy_step`` — no pmean, no per-device key folding, just one
    global program. Returns stacked per-iteration metrics; the host
    fetches them once per log window instead of syncing every step.
    """
    def body(carry, it):
        params, opt_state, key, plans = carry
        plans = maybe_refresh_plans(params, plans, it, cfg, schedule)
        key, k = jax.random.split(key)
        metrics, grads = _loss_grads(params, k, it, cfg, ecfg, tcfg, env,
                                     schedule, plans)
        params, opt_state = rmsprop(params, grads, opt_state, lr=tcfg.lr)
        return (params, opt_state, key, plans), metrics

    its = start + jnp.arange(n, dtype=jnp.int32)
    (params, opt_state, key, plans), metrics = jax.lax.scan(
        body, (params, opt_state, key, plans), its)
    return params, opt_state, key, plans, metrics


_CHUNK_STATICS = ("n", "cfg", "ecfg", "tcfg", "env", "schedule")

_train_chunk = partial(jax.jit, static_argnames=_CHUNK_STATICS)(_scan_chunk)


@functools.lru_cache(maxsize=None)   # one jit (+its trace cache) per mesh
def make_mesh_chunk(mesh: Mesh):
    """jit of ``_scan_chunk`` for the 2-D ``("env", "agent")`` mesh path.

    The learner state (params / optimizer state / plan cache / PRNG key)
    is pinned replicated via ``in_shardings``/``out_shardings`` — IC3Net
    shares weights across agents, so there is nothing per-agent to shard
    in the state. The rollout work partitions instead: the env batch over
    ``env`` and per-agent activations over ``agent``, from the logical
    ``with_sharding_constraint`` hints that become active when the call is
    traced under ``partition.use_constraints(mesh)`` (see ``train``).

    One global program replaces the retired pmap path: the batch is the
    global batch (not per-device), keys are not folded per device, and on
    a (1, 1) mesh the computation is identical to ``_train_chunk`` — the
    parity tests pin that against the host loop.
    """
    repl = NamedSharding(mesh, P())
    return partial(jax.jit, static_argnames=_CHUNK_STATICS,
                   in_shardings=(repl, repl, repl, repl, repl),
                   out_shardings=repl)(_scan_chunk)


def _resolve_mesh(tcfg: TrainConfig) -> Optional[Mesh]:
    """TrainConfig -> Mesh (or None for the plain single-device scan)."""
    shape = tcfg.mesh
    if tcfg.parallel:
        routing = (
            "parallel=True now routes to a 1-D env-only mesh "
            "(mesh=(local_device_count, 1)) where ``batch`` is the GLOBAL "
            "env batch" if shape is None else
            f"the explicit TrainConfig.mesh={shape} wins and parallel=True "
            "is ignored")
        warnings.warn(
            "TrainConfig.parallel is deprecated: the pmap data-parallel "
            f"path was replaced by the jax.sharding mesh engine. {routing};"
            " set TrainConfig.mesh=(env, agent) explicitly.",
            DeprecationWarning, stacklevel=3)
        if shape is None:
            shape = (jax.local_device_count(), 1)
    if shape is None:
        return None
    env_shards, agent_shards = shape
    return make_marl_mesh(env=env_shards, agent=agent_shards)


@contextlib.contextmanager
def _mesh_contexts(mesh: Mesh):
    """Contexts active while tracing/running a mesh chunk.

    ``use_constraints`` switches the logical sharding hints on. On a
    multi-device mesh the FLGW Pallas kernels lower via the shared
    reference impl (``repro.kernels.use_reference_impl``): GSPMD cannot
    partition a pallas custom call — it would replicate the kernel on
    every shard — while the mathematically identical jnp reference shards
    like any einsum (same rationale as ``launch/dryrun``). A (1, 1) mesh
    keeps the kernels, preserving bitwise parity with the scan path.
    """
    ref = (kernels_mod.use_reference_impl if mesh.devices.size > 1
           else contextlib.nullcontext)
    with mesh, partition.use_constraints(mesh), ref():
        yield


_encode_plans = partial(jax.jit, static_argnames=("cfg",))(
    ic3net.encode_plans)

# host-loop mirror of the in-scan refresh: one jitted maybe_refresh keeps
# the host loop bit-identical to the scan carry under every refresh mode
_refresh_plans = partial(jax.jit, static_argnames=("cfg", "schedule"))(
    maybe_refresh_plans)


def _init(cfg, ecfg, env, seed):
    cfg = dataclasses.replace(cfg, obs_dim=env.obs_dim(ecfg),
                              n_agents=ecfg.n_agents,
                              n_actions=env.n_actions(ecfg))
    key = jax.random.PRNGKey(seed)
    kinit, key = jax.random.split(key)
    params, _ = ic3net.init(kinit, cfg)
    return cfg, key, params, rmsprop_init(params)


def train(cfg: ic3net.IC3NetConfig, ecfg=None, tcfg: TrainConfig = None,
          iterations: int = 100, seed: int = 0, log_every: int = 0,
          env: str | envs_mod.Env = "predator_prey",
          schedule: Optional[SparsitySchedule] = None,
          host_loop: bool = False):
    """Train IC3Net on a registered environment; returns (params, history).

    ``history`` is one dict of floats per iteration: success/return/loss,
    the realised ``mask_sparsity``, and host-derived throughput —
    ``steps_per_s`` (training iterations/s), ``env_steps_per_s`` and
    estimated ``sparse_gflops`` (dense-equivalent FLOPs scaled by the
    measured mask sparsity over measured wall time; the first window of
    the scan path includes compile time).
    The default path scans whole log windows on device; with
    ``tcfg.mesh=(env, agent)`` the same scan runs under ``jit`` on a
    ``jax.sharding`` mesh — rollout batch sharded over ``env``, per-agent
    activations over ``agent``, learner state replicated (``tcfg.batch``
    stays the *global* batch). ``host_loop=True`` drives one jitted
    update per iteration from Python (the seed loop, kept for parity
    testing and debugging; it ignores the mesh).
    """
    if isinstance(env, str):
        env = envs_mod.get(env)
    if ecfg is None:
        ecfg = env.config_cls()
    tcfg = tcfg or TrainConfig()
    mesh = None if host_loop else _resolve_mesh(tcfg)
    cfg, key, params, opt_state = _init(cfg, ecfg, env, seed)
    # plan cache: encoded once here, then refreshed inside the loop every
    # schedule.refresh_every iterations ({} when the grouped path is off)
    plans = _encode_plans(params, cfg)
    history: list[dict] = []
    # fwd + ~2x bwd dense-equivalent FLOPs of one training iteration
    # (tcfg.batch is the global env batch on every path)
    flops_iter = (3 * tcfg.batch * ecfg.max_steps
                  * ic3net.flops_per_step(cfg))

    def throughput(ms: dict, n_iters: int, dt: float) -> dict:
        rate = n_iters / max(dt, 1e-9)
        return {
            "steps_per_s": rate,
            "env_steps_per_s": rate * tcfg.batch * ecfg.max_steps,
            "sparse_gflops": rate * flops_iter
            * (1.0 - ms.get("mask_sparsity", 0.0)) / 1e9,
        }

    if host_loop:
        for it in range(iterations):
            if plans:
                plans = _refresh_plans(params, plans, it, cfg=cfg,
                                       schedule=schedule)
            key, k = jax.random.split(key)
            t0 = time.perf_counter()
            params, opt_state, metrics = train_step(
                params, opt_state, k, cfg, ecfg, tcfg, env, schedule, it,
                plans)
            ms = {k2: float(v) for k2, v in metrics.items()}
            ms.update(throughput(ms, 1, time.perf_counter() - t0))
            history.append(ms)
            if log_every and it % log_every == 0:
                print(f"iter {it:5d} success {history[-1]['success']:.3f} "
                      f"return {history[-1]['return']:.3f}")
        return params, history

    mesh_chunk = None if mesh is None else make_mesh_chunk(mesh)

    window = log_every if log_every > 0 else min(max(iterations, 1), 100)
    start = 0
    while start < iterations:
        n = min(window, iterations - start)
        t0 = time.perf_counter()
        if mesh_chunk is not None:
            with _mesh_contexts(mesh):
                params, opt_state, key, plans, metrics = mesh_chunk(
                    params, opt_state, key, plans,
                    jnp.asarray(start, jnp.int32), n,
                    cfg, ecfg, tcfg, env, schedule)
        else:
            params, opt_state, key, plans, metrics = _train_chunk(
                params, opt_state, key, plans,
                jnp.asarray(start, jnp.int32), n,
                cfg, ecfg, tcfg, env, schedule)
        fetched = {k2: np.asarray(v) for k2, v in metrics.items()}  # 1 sync
        dt = time.perf_counter() - t0
        for i in range(n):
            ms = {k2: float(v[i]) for k2, v in fetched.items()}
            ms.update(throughput(ms, n, dt))
            history.append(ms)
        if log_every:
            print(f"iter {start:5d} success {history[start]['success']:.3f} "
                  f"return {history[start]['return']:.3f}")
        start += n

    return params, history
