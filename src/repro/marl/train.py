"""On-device multi-scenario MARL training engine (REINFORCE/A2C + FLGW).

Reproduces the paper's algorithm-validation setup (§IV-A) — IC3Net with
RMSprop lr=1e-3, B parallel environments per iteration, success rate as the
accuracy metric — but generalized along the two axes the paper credits for
its speedup and scope:

* **any registered environment** (``repro.marl.envs``): the loop is written
  against the functional ``Env`` protocol, so Predator-Prey, Traffic
  Junction and Spread (and future scenarios) share one engine;
* **fully on device**: iterations run inside a ``jax.lax.scan`` — the host
  never syncs per step. Metrics are accumulated on device and fetched once
  per log window, mirroring the paper's "fully on-chip training" (the FPGA
  never round-trips to a host between iterations). An optional ``pmap``
  path splits the environment batch across local devices with gradient
  ``pmean``, for data-parallel rollouts.

A FLGW sparsity schedule (``repro.core.schedule.SparsitySchedule``) threads
through the loop: during ``warmup_steps`` the network trains dense, then the
grouping mask switches on — the G ramp the schedule describes. (G itself is
static: IG/OG shapes depend on it.)
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoder, flgw, grouped
from repro.core.schedule import SparsitySchedule
from repro.marl import envs as envs_mod
from repro.marl import ic3net
from repro.optim.optimizers import rmsprop, rmsprop_init


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    batch: int = 16               # parallel envs (paper: B ∈ 1..32)
    lr: float = 1e-3              # paper: RMSprop 0.001
    gamma: float = 0.99
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    gate_coef: float = 0.01       # IC3Net gate regularizer
    parallel: bool = False        # pmap the env batch over local devices


def rollout(params, key, cfg: ic3net.IC3NetConfig, ecfg, env: envs_mod.Env,
            plans=None):
    """One full episode for one env. Returns per-step tensors + success."""
    k_env, k_act = jax.random.split(key)
    state = env.reset(k_env, ecfg)
    hc, gate = ic3net.initial_state(cfg)

    def step_fn(carry, k):
        state, hc, gate, done = carry
        obs = env.observe(state, ecfg)
        logits, value, gate_logits, hc = ic3net.policy_step(
            params, cfg, obs, hc, gate, plans)
        action = jax.random.categorical(k, logits)              # (A,)
        logp = jax.nn.log_softmax(logits)
        logp_a = jnp.take_along_axis(logp, action[:, None], 1)[:, 0]
        entropy = -jnp.sum(jax.nn.softmax(logits) * logp, axis=-1)
        kg, _ = jax.random.split(k)
        new_gate = jax.random.bernoulli(
            kg, jax.nn.softmax(gate_logits)[:, 1]).astype(jnp.float32)
        nstate, reward, ndone = env.step(state, action, ecfg)
        # freeze transitions after done
        reward = jnp.where(done, 0.0, reward)
        nstate = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), state, nstate)
        out = (reward, logp_a, value, entropy,
               jax.nn.log_softmax(gate_logits)[:, 1] * new_gate, new_gate)
        return (nstate, hc, new_gate, done | ndone), out

    keys = jax.random.split(k_act, ecfg.max_steps)
    (state, _, _, _), (rew, logp, val, ent, gate_logp, gates) = \
        jax.lax.scan(step_fn, (state, hc, gate,
                               jnp.zeros((), bool)), keys)
    return rew, logp, val, ent, gate_logp, gates, env.success(state)


def a2c_loss(params, key, cfg, ecfg, tcfg: TrainConfig, env: envs_mod.Env,
             plans=None):
    keys = jax.random.split(key, tcfg.batch)
    rew, logp, val, ent, gate_logp, gates, succ = jax.vmap(
        lambda k: rollout(params, k, cfg, ecfg, env, plans))(keys)
    # returns-to-go, (B, T, A)
    def disc(carry, r):
        carry = r + tcfg.gamma * carry
        return carry, carry
    _, returns = jax.lax.scan(disc, jnp.zeros_like(rew[:, 0]),
                              rew[:, ::-1].swapaxes(0, 1))
    returns = returns[::-1].swapaxes(0, 1)                    # (B, T, A)
    adv = returns - val
    pg = -jnp.mean(logp * jax.lax.stop_gradient(adv))
    vloss = jnp.mean(adv ** 2)
    eloss = -jnp.mean(ent)
    gloss = jnp.mean(gates)                                   # talk less
    loss = pg + tcfg.value_coef * vloss + tcfg.entropy_coef * eloss \
        + tcfg.gate_coef * gloss
    return loss, {"success": jnp.mean(succ.astype(jnp.float32)),
                  "return": jnp.mean(jnp.sum(rew, axis=1)),
                  "loss": loss}


def _mean_mask_sparsity(params, cfg: ic3net.IC3NetConfig) -> jax.Array:
    """Mean realised mask sparsity over the FLGW layers (0 when dense)."""
    fl = cfg.flgw
    if fl is None:
        return jnp.zeros(())
    vals = [flgw.mask_sparsity(*flgw.grouping_indices(p["ig"], p["og"]),
                               fl.groups)
            for _, p in grouped.iter_flgw_layers(params)]
    return jnp.mean(jnp.stack(vals)) if vals else jnp.zeros(())


def maybe_refresh_plans(params, plans, it, cfg: ic3net.IC3NetConfig,
                        schedule: Optional[SparsitySchedule]):
    """Amortized OSEL: re-encode the FLGW plan cache only when due.

    ``plans`` is the PlanState carried through the training loop;
    :func:`repro.core.encoder.maybe_refresh` decides per the schedule's
    ``refresh`` mode — fixed period (``it % refresh_every == 0``), or
    change-driven from the carried argmax signature — and re-encodes via
    one ``encode_plans`` pass, reusing the stale plans otherwise. The
    empty state (non-grouped configs) passes through untouched; ``it`` may
    be a traced int32 (``lax.cond`` inside).
    """
    if not plans:
        return plans
    return encoder.maybe_refresh(params, plans, it, cfg.flgw, schedule)


def _loss_grads(params, key, it, cfg, ecfg, tcfg, env,
                schedule: Optional[SparsitySchedule], plans=None):
    """(metrics, grads) at global iteration ``it`` (traced int32).

    With a schedule, the first ``warmup_steps`` iterations run the dense
    path (mask off) via ``lax.cond`` — both branches share the same param
    tree, so the G ramp happens inside the compiled loop. ``plans`` is the
    cached sparse metadata consumed by the grouped path.
    """
    def vag(c):
        def f(p, k):
            return jax.value_and_grad(a2c_loss, has_aux=True)(
                p, k, c, ecfg, tcfg, env, plans)
        return f

    ramped = (schedule is not None and schedule.warmup_steps > 0
              and cfg.flgw is not None)
    if ramped:
        dense_cfg = dataclasses.replace(cfg, flgw_path="dense")
        (_, metrics), grads = jax.lax.cond(
            schedule.sparse_at(it), vag(cfg), vag(dense_cfg), params, key)
    else:
        (_, metrics), grads = vag(cfg)(params, key)
    metrics = dict(metrics)
    # report the sparsity of the compute that actually ran: 0 on warmup
    # iterations, where the dense branch executed full FLOPs
    sparsity = _mean_mask_sparsity(params, cfg)
    if ramped:
        sparsity = jnp.where(schedule.sparse_at(it), sparsity, 0.0)
    metrics["mask_sparsity"] = sparsity
    return metrics, grads


@partial(jax.jit, static_argnames=("cfg", "ecfg", "tcfg", "env", "schedule"))
def train_step(params, opt_state, key, cfg, ecfg, tcfg: TrainConfig,
               env: envs_mod.Env = None, schedule=None,
               it: jax.Array | int = 0, plans=None):
    """One host-driven update (seed-compatible API; used for parity tests)."""
    env = env or envs_mod.PREDATOR_PREY
    metrics, grads = _loss_grads(params, key, jnp.asarray(it, jnp.int32),
                                 cfg, ecfg, tcfg, env, schedule, plans)
    params, opt_state = rmsprop(params, grads, opt_state, lr=tcfg.lr)
    return params, opt_state, metrics


def _scan_chunk(params, opt_state, key, plans, start, n, cfg, ecfg, tcfg,
                env, schedule, axis=None):
    """``n`` update iterations as one on-device ``lax.scan``.

    The FLGW plan cache rides in the carry: each iteration first passes
    through ``maybe_refresh_plans`` — a ``lax.cond`` that re-encodes the
    sparse metadata every ``schedule.refresh_every`` steps and reuses the
    carried (stale) plans otherwise, so the grouped Pallas kernel runs
    against amortized metadata inside the compiled loop.

    ``axis`` names the pmap axis for gradient/metric ``pmean`` (None on the
    single-device path — the only difference between the two). Returns
    stacked per-iteration metrics; the host fetches them once per log
    window instead of syncing every step.
    """
    def body(carry, it):
        params, opt_state, key, plans = carry
        plans = maybe_refresh_plans(params, plans, it, cfg, schedule)
        key, k = jax.random.split(key)
        metrics, grads = _loss_grads(params, k, it, cfg, ecfg, tcfg, env,
                                     schedule, plans)
        if axis is not None:
            grads = jax.lax.pmean(grads, axis)
            metrics = jax.lax.pmean(metrics, axis)
        params, opt_state = rmsprop(params, grads, opt_state, lr=tcfg.lr)
        return (params, opt_state, key, plans), metrics

    its = start + jnp.arange(n, dtype=jnp.int32)
    (params, opt_state, key, plans), metrics = jax.lax.scan(
        body, (params, opt_state, key, plans), its)
    return params, opt_state, key, plans, metrics


_train_chunk = partial(jax.jit,
                       static_argnames=("n", "cfg", "ecfg", "tcfg", "env",
                                        "schedule", "axis"))(_scan_chunk)

# data-parallel chunk: each device rolls out tcfg.batch envs, the RMSprop
# update stays replicated because the pmean'd grads are identical
_train_chunk_pmap = partial(jax.pmap, axis_name="dev",
                            static_broadcasted_argnums=(5, 6, 7, 8, 9, 10))(
    partial(_scan_chunk, axis="dev"))

_encode_plans = partial(jax.jit, static_argnames=("cfg",))(
    ic3net.encode_plans)

# host-loop mirror of the in-scan refresh: one jitted maybe_refresh keeps
# the host loop bit-identical to the scan carry under every refresh mode
_refresh_plans = partial(jax.jit, static_argnames=("cfg", "schedule"))(
    maybe_refresh_plans)


def _init(cfg, ecfg, env, seed):
    cfg = dataclasses.replace(cfg, obs_dim=env.obs_dim(ecfg),
                              n_agents=ecfg.n_agents,
                              n_actions=env.n_actions(ecfg))
    key = jax.random.PRNGKey(seed)
    kinit, key = jax.random.split(key)
    params, _ = ic3net.init(kinit, cfg)
    return cfg, key, params, rmsprop_init(params)


def train(cfg: ic3net.IC3NetConfig, ecfg=None, tcfg: TrainConfig = None,
          iterations: int = 100, seed: int = 0, log_every: int = 0,
          env: str | envs_mod.Env = "predator_prey",
          schedule: Optional[SparsitySchedule] = None,
          host_loop: bool = False):
    """Train IC3Net on a registered environment; returns (params, history).

    ``history`` is one dict of floats per iteration: success/return/loss,
    the realised ``mask_sparsity``, and host-derived throughput —
    ``steps_per_s`` (training iterations/s), ``env_steps_per_s`` and
    estimated ``sparse_gflops`` (dense-equivalent FLOPs scaled by the
    measured mask sparsity over measured wall time; the first window of
    the scan path includes compile time).
    The default path scans whole log windows on device; ``host_loop=True``
    drives one jitted update per iteration from Python (the seed loop,
    kept for parity testing and debugging).
    """
    if isinstance(env, str):
        env = envs_mod.get(env)
    if ecfg is None:
        ecfg = env.config_cls()
    tcfg = tcfg or TrainConfig()
    cfg, key, params, opt_state = _init(cfg, ecfg, env, seed)
    # plan cache: encoded once here, then refreshed inside the loop every
    # schedule.refresh_every iterations ({} when the grouped path is off)
    plans = _encode_plans(params, cfg)
    history: list[dict] = []
    ndev = jax.local_device_count()
    use_pmap = not host_loop and tcfg.parallel and ndev > 1
    # fwd + ~2x bwd dense-equivalent FLOPs of one training iteration;
    # the pmap path rolls out tcfg.batch envs on *each* device
    world = ndev if use_pmap else 1
    flops_iter = (3 * world * tcfg.batch * ecfg.max_steps
                  * ic3net.flops_per_step(cfg))

    def throughput(ms: dict, n_iters: int, dt: float) -> dict:
        rate = n_iters / max(dt, 1e-9)
        return {
            "steps_per_s": rate,
            "env_steps_per_s": rate * world * tcfg.batch * ecfg.max_steps,
            "sparse_gflops": rate * flops_iter
            * (1.0 - ms.get("mask_sparsity", 0.0)) / 1e9,
        }

    if host_loop:
        for it in range(iterations):
            if plans:
                plans = _refresh_plans(params, plans, it, cfg=cfg,
                                       schedule=schedule)
            key, k = jax.random.split(key)
            t0 = time.perf_counter()
            params, opt_state, metrics = train_step(
                params, opt_state, k, cfg, ecfg, tcfg, env, schedule, it,
                plans)
            ms = {k2: float(v) for k2, v in metrics.items()}
            ms.update(throughput(ms, 1, time.perf_counter() - t0))
            history.append(ms)
            if log_every and it % log_every == 0:
                print(f"iter {it:5d} success {history[-1]['success']:.3f} "
                      f"return {history[-1]['return']:.3f}")
        return params, history

    if use_pmap:
        # replicate learner state; each device gets an independent key
        params = jax.device_put_replicated(params, jax.local_devices())
        opt_state = jax.device_put_replicated(opt_state, jax.local_devices())
        plans = jax.device_put_replicated(plans, jax.local_devices())
        key = jax.vmap(jax.random.fold_in, (None, 0))(
            key, jnp.arange(ndev, dtype=jnp.uint32))

    window = log_every if log_every > 0 else min(max(iterations, 1), 100)
    start = 0
    while start < iterations:
        n = min(window, iterations - start)
        t0 = time.perf_counter()
        if use_pmap:
            starts = jnp.full((ndev,), start, jnp.int32)
            params, opt_state, key, plans, metrics = _train_chunk_pmap(
                params, opt_state, key, plans, starts, n, cfg, ecfg, tcfg,
                env, schedule)
            metrics = jax.tree.map(lambda m: m[0], metrics)  # replicated
        else:
            params, opt_state, key, plans, metrics = _train_chunk(
                params, opt_state, key, plans,
                jnp.asarray(start, jnp.int32), n,
                cfg, ecfg, tcfg, env, schedule)
        fetched = {k2: np.asarray(v) for k2, v in metrics.items()}  # 1 sync
        dt = time.perf_counter() - t0
        for i in range(n):
            ms = {k2: float(v[i]) for k2, v in fetched.items()}
            ms.update(throughput(ms, n, dt))
            history.append(ms)
        if log_every:
            print(f"iter {start:5d} success {history[start]['success']:.3f} "
                  f"return {history[start]['return']:.3f}")
        start += n

    if use_pmap:
        params = jax.tree.map(lambda p: p[0], params)
    return params, history
