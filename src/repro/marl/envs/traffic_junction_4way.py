"""Traffic Junction, 4-way variant — two two-way roads, curved routes.

IC3Net's hardest junction regime: two 2-lane roads cross in the middle of
a ``size × size`` grid (``size`` even), giving four entry arms; each car
picks one of three turns at the junction — right, straight or left — for
12 distinct routes, several of which genuinely curve through the shared
2×2 intersection. Right-hand traffic fixes the lanes (``m = size // 2``):
eastbound row ``m``, westbound row ``m - 1``, southbound column ``m - 1``,
northbound column ``m``.

Route geometry is *static*: arm 0 (from the west) is written out by hand
and arms 1–3 follow by 90° grid rotations, yielding a cached
``(12, Lmax, 2)`` cell table plus per-route lengths. Cars are just
``(route, progress)`` indices into that table, so ``reset``/``step``/
``observe`` stay pure, fixed-shape and vmap/scan-friendly like every
registered env — the training engine's on-device ``lax.scan`` batches
thousands of these next to the learner.

Arrivals follow the hard variant's Geometric(``p_arrive``) stream with
strictly increasing entry steps (collisions must come from policy, not
the spawner); dynamics, rewards and the success criterion (no collision
AND every car cleared) mirror :mod:`~repro.marl.envs.traffic_junction`,
whose ``EnvState`` is reused unchanged.
"""
from __future__ import annotations

from functools import lru_cache
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.marl.envs.traffic_junction import (EnvState, arrival_stream,
                                              occupancy_window, success)

__all__ = ["EnvConfig", "EnvState", "reset", "step", "observe", "success",
           "obs_dim", "n_actions", "positions", "active"]

N_ACTIONS = 2   # 0 = brake, 1 = gas
N_ROUTES = 12   # 4 arms x {right, straight, left}


class EnvConfig(NamedTuple):
    n_agents: int = 6
    size: int = 8                     # even; roads are 2 lanes wide
    vision: int = 1
    max_steps: int = 40
    time_penalty: float = -0.01
    collision_penalty: float = -1.0
    p_arrive: float = 0.5             # per-step arrival probability


@lru_cache(maxsize=None)
def _route_table(size: int) -> tuple[np.ndarray, np.ndarray]:
    """Static route geometry: (12, Lmax, 2) int32 cells + (12,) lengths.

    Routes are ordered ``arm * 3 + turn`` with arms counter-enumerated by
    successive clockwise rotations starting from the west (0 = west,
    1 = north, 2 = east, 3 = south) and turns (0 = right, 1 = straight,
    2 = left). Paths shorter than ``Lmax`` are padded with their exit
    cell, so clipping ``prog`` into the table always lands on-route.
    """
    if size % 2 or size < 4:
        raise ValueError(f"4-way junction needs an even size >= 4, "
                         f"got {size}")
    m = size // 2
    east = [(m, c) for c in range(size)]                   # straight
    # right turn: leave the eastbound lane at (m, m-1), merge onto the
    # southbound lane (col m-1) just past the intersection
    right = east[:m] + [(r, m - 1) for r in range(m + 1, size)]
    # left turn: cross to (m, m), then up the northbound lane (col m)
    left = east[:m + 1] + [(r, m) for r in range(m - 1, -1, -1)]

    def rot(path):   # 90° clockwise: west arm -> north arm -> east -> south
        return [(c, size - 1 - r) for r, c in path]

    routes, arm = [], [right, east, left]
    for _ in range(4):
        routes.extend(arm)
        arm = [rot(p) for p in arm]
    lmax = max(len(p) for p in routes)
    table = np.stack([np.asarray(p + [p[-1]] * (lmax - len(p)), np.int32)
                      for p in routes])
    lens = np.asarray([len(p) for p in routes], np.int32)
    return table, lens


def _lmax(cfg: EnvConfig) -> int:
    return cfg.size + 1          # the left turn: m+1 cells in, m cells out


def obs_dim(cfg: EnvConfig) -> int:
    # route one-hot (12) + progress one-hot (Lmax+1) + on-road flag
    # + occupancy window of the other cars ((2v+1)^2)
    return N_ROUTES + _lmax(cfg) + 1 + 1 + (2 * cfg.vision + 1) ** 2


def n_actions(cfg: EnvConfig) -> int:
    return N_ACTIONS


def _route_len(route: jax.Array, cfg: EnvConfig) -> jax.Array:
    _, lens = _route_table(cfg.size)
    return jnp.asarray(lens)[route]


def positions(state: EnvState, cfg: EnvConfig) -> jax.Array:
    """(A, 2) int32 grid cells; exited cars clip to their exit cell."""
    table, _ = _route_table(cfg.size)
    tbl = jnp.asarray(table)
    return tbl[state.route, jnp.clip(state.prog, 0, tbl.shape[1] - 1)]


def active(state: EnvState, cfg: EnvConfig) -> jax.Array:
    """(A,) bool — entered and not yet past the end of its route."""
    return (state.t >= state.enter_t) & \
        (state.prog < _route_len(state.route, cfg))


def reset(key: jax.Array, cfg: EnvConfig) -> EnvState:
    kr, ke = jax.random.split(key)
    a = cfg.n_agents
    route = jax.random.randint(kr, (a,), 0, N_ROUTES, jnp.int32)
    enter_t = arrival_stream(ke, a, cfg.p_arrive,
                             cfg.max_steps - _lmax(cfg) - 1)
    return EnvState(route=route, enter_t=enter_t,
                    prog=jnp.zeros((a,), jnp.int32),
                    collided=jnp.zeros((), bool),
                    cleared=jnp.zeros((), bool),
                    t=jnp.zeros((), jnp.int32))


def observe(state: EnvState, cfg: EnvConfig) -> jax.Array:
    """(A, obs_dim) float32 observations."""
    act = active(state, cfg)
    pos = positions(state, cfg)
    lmax = _lmax(cfg)
    route_oh = jax.nn.one_hot(state.route, N_ROUTES)
    prog_oh = jax.nn.one_hot(jnp.clip(state.prog, 0, lmax), lmax + 1)
    occ = occupancy_window(pos, act, cfg.vision)
    return jnp.concatenate(
        [route_oh, prog_oh, act[:, None].astype(jnp.float32), occ], axis=1)


def step(state: EnvState, actions: jax.Array,
         cfg: EnvConfig) -> tuple[EnvState, jax.Array, jax.Array]:
    """actions: (A,) int32 ∈ {0, 1}. Returns (new_state, rewards (A,), done)."""
    plen = _route_len(state.route, cfg)
    act = active(state, cfg)
    gas = (actions > 0) & act
    prog = jnp.minimum(state.prog + gas.astype(jnp.int32), plen)
    nstate = state._replace(prog=prog)
    # activity at the *post-step* time: a car entering at t+1 spawns onto
    # its entry cell now, so sitting on that cell is a collision already
    now = (state.t + 1 >= state.enter_t) & (prog < plen)
    pos = positions(nstate, cfg)
    # cell id per car; off-road cars get a unique sentinel so they never match
    cell = pos[:, 0] * cfg.size + pos[:, 1]
    cell = jnp.where(now, cell,
                     cfg.size * cfg.size + jnp.arange(cfg.n_agents))
    share = jnp.sum(cell[:, None] == cell[None, :], axis=1) - 1
    coll = share > 0                                         # (A,) bool
    tau = (state.t + 1 - state.enter_t).astype(jnp.float32)
    rewards = jnp.where(
        now,
        cfg.time_penalty * tau
        + cfg.collision_penalty * coll.astype(jnp.float32),
        0.0)
    t = state.t + 1
    cleared = jnp.all(prog >= plen)
    done = cleared | (t >= cfg.max_steps)
    return EnvState(route=state.route, enter_t=state.enter_t, prog=prog,
                    collided=state.collided | jnp.any(coll),
                    cleared=cleared, t=t), \
        rewards, done
