"""Multi-scenario MARL environment registry.

Every environment is a module of pure functions over NamedTuple pytrees —
``reset``/``step``/``observe``/``success`` plus the static helpers
``obs_dim``/``n_actions`` — bundled into an :class:`Env` record and
registered under a string key. The training engine (``repro.marl.train``)
is written against this protocol only, so a new scenario is one module plus
one ``register`` call and every benchmark/example sweeps it for free.

All bundled environments are vmap/scan friendly: states are pytrees of
fixed-shape arrays, ``reset``/``step`` are pure, and nothing branches on
traced values — thousands of envs batch on device next to the learner.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.marl.envs import (predator_prey, spread, traffic_junction,
                             traffic_junction_4way)


@dataclasses.dataclass(frozen=True)
class Env:
    """One registered environment: its config type plus pure functions.

    Frozen (hashable) so an ``Env`` can ride through ``jax.jit`` as a
    static argument.
    """

    name: str
    config_cls: type
    reset: Callable[..., Any]          # (key, cfg) -> state
    step: Callable[..., Any]           # (state, actions, cfg) -> (state, rew, done)
    observe: Callable[..., Any]        # (state, cfg) -> (A, obs_dim) obs
    success: Callable[..., Any]        # (state,) -> () bool
    obs_dim: Callable[..., int]        # (cfg,) -> int
    n_actions: Callable[..., int]      # (cfg,) -> int

    def default_config(self, **overrides):
        return self.config_cls(**overrides)


_REGISTRY: dict[str, Env] = {}


def register(env: Env) -> Env:
    if env.name in _REGISTRY:
        raise ValueError(f"environment {env.name!r} already registered")
    _REGISTRY[env.name] = env
    return env


def get(name: str) -> Env:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown environment {name!r}; registered: {names()}") from None


def names() -> list[str]:
    return sorted(_REGISTRY)


def make(name: str, **overrides) -> tuple[Env, Any]:
    """Look up an environment and build its config in one call."""
    env = get(name)
    return env, env.default_config(**overrides)


def _register_module(name: str, mod) -> Env:
    return register(Env(
        name=name, config_cls=mod.EnvConfig, reset=mod.reset, step=mod.step,
        observe=mod.observe, success=mod.success, obs_dim=mod.obs_dim,
        n_actions=mod.n_actions))


PREDATOR_PREY = _register_module("predator_prey", predator_prey)
TRAFFIC_JUNCTION = _register_module("traffic_junction", traffic_junction)
SPREAD = _register_module("spread", spread)

# 4-way TJ: two two-way roads with right/straight/left turning routes —
# 12 curved routes through a shared 2x2 intersection (its own module).
TRAFFIC_JUNCTION_4WAY = _register_module("traffic_junction_4way",
                                         traffic_junction_4way)

# Hard TJ: same step/observe dynamics, but a bigger grid, more cars and a
# dense Bernoulli(p_arrive) arrival stream (its own config + reset).
TRAFFIC_JUNCTION_HARD = register(Env(
    name="traffic_junction_hard",
    config_cls=traffic_junction.HardConfig,
    reset=traffic_junction.reset_hard,
    step=traffic_junction.step,
    observe=traffic_junction.observe,
    success=traffic_junction.success,
    obs_dim=traffic_junction.obs_dim,
    n_actions=traffic_junction.n_actions))
