"""Cooperative navigation ("spread") — a gridworld take on MPE simple-spread.

``A`` agents must cover ``A`` landmarks on a ``size × size`` grid. All
agents share a cooperative reward: the mean (over landmarks) distance to
the nearest agent, negated — improving coverage anywhere pays everyone —
plus a per-agent bonus for standing on a landmark. The episode succeeds
when every landmark is occupied by at least one agent, which requires the
team to *spread out* rather than converge on the closest landmark.

Like the other registered environments this is pure and fixed-shape:
``reset``/``step`` are jit/vmap-friendly and the state is a pytree of
arrays, so batched rollouts run fully on device.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class EnvConfig(NamedTuple):
    n_agents: int = 3
    size: int = 5
    vision: int = 1                  # unused; kept for protocol symmetry
    max_steps: int = 20
    occupy_reward: float = 0.25
    cover_bonus: float = 0.5


class EnvState(NamedTuple):
    pos: jax.Array        # (A, 2) int32 agent positions
    landmarks: jax.Array  # (A, 2) int32 landmark positions
    t: jax.Array          # () int32


# actions: 0=stay, 1=up, 2=down, 3=left, 4=right
# numpy so importing this module stays free of JAX computations (a
# device-committed constant here would lock out jax.distributed.initialize)
_MOVES = np.array([[0, 0], [-1, 0], [1, 0], [0, -1], [0, 1]], np.int32)
N_ACTIONS = 5


def obs_dim(cfg: EnvConfig) -> int:
    # own position one-hot (2·size) + per-landmark offset (2·A, normalized)
    # + per-landmark covered flag (A)
    return 2 * cfg.size + 3 * cfg.n_agents


def n_actions(cfg: EnvConfig) -> int:
    return N_ACTIONS


def reset(key: jax.Array, cfg: EnvConfig) -> EnvState:
    ka, kl = jax.random.split(key)
    pos = jax.random.randint(ka, (cfg.n_agents, 2), 0, cfg.size, jnp.int32)
    landmarks = jax.random.randint(kl, (cfg.n_agents, 2), 0, cfg.size,
                                   jnp.int32)
    return EnvState(pos=pos, landmarks=landmarks,
                    t=jnp.zeros((), jnp.int32))


def _coverage(state: EnvState) -> jax.Array:
    """(A,) bool — is each landmark occupied by some agent."""
    same = jnp.all(state.pos[:, None, :] == state.landmarks[None, :, :],
                   axis=-1)                                  # (agent, lm)
    return jnp.any(same, axis=0)


def observe(state: EnvState, cfg: EnvConfig) -> jax.Array:
    """(A, obs_dim) float32 observations."""
    row = jax.nn.one_hot(state.pos[:, 0], cfg.size)
    col = jax.nn.one_hot(state.pos[:, 1], cfg.size)
    off = state.landmarks[None, :, :] - state.pos[:, None, :]  # (A, L, 2)
    off = off.astype(jnp.float32) / max(cfg.size - 1, 1)
    covered = _coverage(state).astype(jnp.float32)             # (L,)
    a = cfg.n_agents
    return jnp.concatenate(
        [row, col, off.reshape(a, -1),
         jnp.broadcast_to(covered[None, :], (a, a))], axis=1)


def step(state: EnvState, actions: jax.Array,
         cfg: EnvConfig) -> tuple[EnvState, jax.Array, jax.Array]:
    """actions: (A,) int32. Returns (new_state, rewards (A,), done ())."""
    pos = jnp.clip(state.pos + jnp.asarray(_MOVES)[actions],
                   0, cfg.size - 1)
    nstate = EnvState(pos=pos, landmarks=state.landmarks, t=state.t + 1)
    # shared shaping: mean over landmarks of the distance to the nearest agent
    dist = jnp.sum(jnp.abs(pos[:, None, :] - state.landmarks[None, :, :]),
                   axis=-1)                                   # (agent, lm)
    nearest = jnp.min(dist, axis=0).astype(jnp.float32)       # (lm,)
    shared = -jnp.mean(nearest) / max(cfg.size, 1)
    covered = _coverage(nstate)
    all_covered = jnp.all(covered)
    occupy = jnp.any(jnp.all(pos[:, None, :] == state.landmarks[None, :, :],
                             axis=-1), axis=1)                # (agent,)
    rewards = shared + cfg.occupy_reward * occupy.astype(jnp.float32) \
        + cfg.cover_bonus * all_covered.astype(jnp.float32)
    done = all_covered | (nstate.t >= cfg.max_steps)
    return nstate, rewards, done


def success(state: EnvState) -> jax.Array:
    return jnp.all(_coverage(state))
