"""Predator-Prey — pure-JAX cooperative gridworld (paper §IV-A).

``A`` cooperative predators search a ``size × size`` grid for one stationary
prey. Agents observe their own position (one-hot) and, within ``vision``
Chebyshev distance, the prey's relative offset. An agent standing on the
prey is "arrived"; the episode succeeds when every predator has arrived.
Reward shaping follows IC3Net's cooperative mode: a small time penalty while
searching, a positive reward on the prey cell.

Everything is functional and vmap/scan friendly: ``reset`` and ``step`` are
pure, states are pytrees of arrays, so thousands of environments batch on
device next to the learner — the host never emulates physics step-by-step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class EnvConfig(NamedTuple):
    n_agents: int = 3
    size: int = 5
    vision: int = 1
    max_steps: int = 20
    step_penalty: float = -0.05
    prey_reward: float = 0.5


class EnvState(NamedTuple):
    pos: jax.Array        # (A, 2) int32 agent positions
    prey: jax.Array       # (2,) int32
    arrived: jax.Array    # (A,) bool — has each agent reached the prey
    t: jax.Array          # () int32


# actions: 0=stay, 1=up, 2=down, 3=left, 4=right
# numpy so importing this module stays free of JAX computations (a
# device-committed constant here would lock out jax.distributed.initialize)
_MOVES = np.array([[0, 0], [-1, 0], [1, 0], [0, -1], [0, 1]], np.int32)
N_ACTIONS = 5


def obs_dim(cfg: EnvConfig) -> int:
    # own position one-hot (2·size) + prey offset one-hot ((2v+1)^2) + seen flag
    return 2 * cfg.size + (2 * cfg.vision + 1) ** 2 + 1


def n_actions(cfg: EnvConfig) -> int:
    return N_ACTIONS


def reset(key: jax.Array, cfg: EnvConfig) -> EnvState:
    kp, ka = jax.random.split(key)
    prey = jax.random.randint(kp, (2,), 0, cfg.size, jnp.int32)
    pos = jax.random.randint(ka, (cfg.n_agents, 2), 0, cfg.size, jnp.int32)
    return EnvState(pos=pos, prey=prey,
                    arrived=jnp.zeros((cfg.n_agents,), bool),
                    t=jnp.zeros((), jnp.int32))


def observe(state: EnvState, cfg: EnvConfig) -> jax.Array:
    """(A, obs_dim) float32 observations."""
    a = cfg.n_agents
    row = jax.nn.one_hot(state.pos[:, 0], cfg.size)
    col = jax.nn.one_hot(state.pos[:, 1], cfg.size)
    off = state.prey[None, :] - state.pos                    # (A, 2)
    seen = jnp.all(jnp.abs(off) <= cfg.vision, axis=1)       # (A,)
    v = 2 * cfg.vision + 1
    oidx = (off[:, 0] + cfg.vision) * v + (off[:, 1] + cfg.vision)
    prey_oh = jax.nn.one_hot(jnp.clip(oidx, 0, v * v - 1), v * v)
    prey_oh = prey_oh * seen[:, None]
    return jnp.concatenate(
        [row, col, prey_oh, seen[:, None].astype(jnp.float32)], axis=1)


def step(state: EnvState, actions: jax.Array,
         cfg: EnvConfig) -> tuple[EnvState, jax.Array, jax.Array]:
    """actions: (A,) int32. Returns (new_state, rewards (A,), done ())."""
    # Arrived agents stay on the prey (IC3Net freezes them).
    moves = jnp.where(state.arrived[:, None], 0,
                      jnp.asarray(_MOVES)[actions])
    pos = jnp.clip(state.pos + moves, 0, cfg.size - 1)
    on_prey = jnp.all(pos == state.prey[None, :], axis=1)
    arrived = state.arrived | on_prey
    rewards = jnp.where(arrived, cfg.prey_reward, cfg.step_penalty)
    t = state.t + 1
    done = jnp.all(arrived) | (t >= cfg.max_steps)
    return EnvState(pos=pos, prey=state.prey, arrived=arrived, t=t), \
        rewards, done


def success(state: EnvState) -> jax.Array:
    return jnp.all(state.arrived)
