"""Traffic Junction — pure-JAX port of IC3Net's second benchmark.

Two one-way roads cross at the centre of a ``size × size`` grid: route 0
drives the middle row left→right, route 1 the middle column top→bottom.
Each of the ``A`` cars is assigned a route and a distinct entry step at
reset (staggered entries, so collisions are a consequence of policy — not
of spawning). Actions are binary: 0 = brake (hold position), 1 = gas
(advance one cell along the route). Two cars on the same cell collide;
each car also pays a time penalty proportional to how long it has been on
the road, so the learned trade-off is "brake near the junction but do not
dawdle" — the coordination problem communication is supposed to solve.

An episode *succeeds* iff no collision happened before every car cleared
the grid (IC3Net's success criterion). Everything is pure and fixed-shape:
cars that have exited (or not yet entered) are masked, never removed.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EnvConfig(NamedTuple):
    n_agents: int = 4
    size: int = 7
    vision: int = 1
    max_steps: int = 24
    time_penalty: float = -0.01       # ·τ (steps since entry) per step
    collision_penalty: float = -1.0


class EnvState(NamedTuple):
    route: jax.Array      # (A,) int32 ∈ {0, 1}
    enter_t: jax.Array    # (A,) int32 — step at which each car enters
    prog: jax.Array       # (A,) int32 ∈ [0, size]; == size ⇒ exited
    collided: jax.Array   # () bool — any collision so far this episode
    cleared: jax.Array    # () bool — have all cars exited the grid
    t: jax.Array          # () int32


N_ACTIONS = 2  # 0 = brake, 1 = gas


def obs_dim(cfg: EnvConfig) -> int:
    # route one-hot (2) + progress one-hot (size+1) + on-road flag
    # + occupancy window of the other cars ((2v+1)^2)
    return 2 + cfg.size + 1 + 1 + (2 * cfg.vision + 1) ** 2


def n_actions(cfg: EnvConfig) -> int:
    return N_ACTIONS


def positions(state: EnvState, cfg: EnvConfig) -> jax.Array:
    """(A, 2) int32 grid cells; exited cars are clipped to the last cell."""
    mid = cfg.size // 2
    p = jnp.clip(state.prog, 0, cfg.size - 1)
    on_row = jnp.stack([jnp.full_like(p, mid), p], axis=1)   # route 0
    on_col = jnp.stack([p, jnp.full_like(p, mid)], axis=1)   # route 1
    return jnp.where(state.route[:, None] == 0, on_row, on_col)


def active(state: EnvState, cfg: EnvConfig) -> jax.Array:
    """(A,) bool — entered and not yet exited."""
    return (state.t >= state.enter_t) & (state.prog < cfg.size)


def reset(key: jax.Array, cfg: EnvConfig) -> EnvState:
    kr, ke = jax.random.split(key)
    a = cfg.n_agents
    route = jax.random.bernoulli(kr, 0.5, (a,)).astype(jnp.int32)
    # distinct entry steps: collisions come from policy, not the spawner
    enter_t = jax.random.permutation(ke, jnp.arange(a, dtype=jnp.int32))
    return EnvState(route=route, enter_t=enter_t,
                    prog=jnp.zeros((a,), jnp.int32),
                    collided=jnp.zeros((), bool),
                    cleared=jnp.zeros((), bool),
                    t=jnp.zeros((), jnp.int32))


def occupancy_window(pos: jax.Array, act: jax.Array,
                     vision: int) -> jax.Array:
    """(A, (2v+1)²) occupancy of the *other* active cars around each car.

    Shared by every junction variant — the vision window does not care
    about route topology, only about grid positions and activity masks.
    """
    a = pos.shape[0]
    v = vision
    w = 2 * v + 1
    off = pos[None, :, :] - pos[:, None, :]                  # (A, A, 2)
    inwin = jnp.all(jnp.abs(off) <= v, axis=-1)
    inwin = inwin & act[None, :] & act[:, None]
    inwin = inwin & ~jnp.eye(a, dtype=bool)
    widx = (off[..., 0] + v) * w + (off[..., 1] + v)
    occ = jnp.sum(jax.nn.one_hot(jnp.clip(widx, 0, w * w - 1), w * w)
                  * inwin[..., None], axis=1)
    return jnp.clip(occ, 0.0, 1.0)                           # (A, w²)


def observe(state: EnvState, cfg: EnvConfig) -> jax.Array:
    """(A, obs_dim) float32 observations."""
    act = active(state, cfg)
    pos = positions(state, cfg)
    route_oh = jax.nn.one_hot(state.route, 2)
    prog_oh = jax.nn.one_hot(jnp.clip(state.prog, 0, cfg.size), cfg.size + 1)
    occ = occupancy_window(pos, act, cfg.vision)
    return jnp.concatenate(
        [route_oh, prog_oh, act[:, None].astype(jnp.float32), occ], axis=1)


def step(state: EnvState, actions: jax.Array,
         cfg: EnvConfig) -> tuple[EnvState, jax.Array, jax.Array]:
    """actions: (A,) int32 ∈ {0, 1}. Returns (new_state, rewards (A,), done)."""
    act = active(state, cfg)
    gas = (actions > 0) & act
    prog = jnp.clip(state.prog + gas.astype(jnp.int32), 0, cfg.size)
    nstate = state._replace(prog=prog)
    # activity at the *post-step* time: a car entering at t+1 spawns onto
    # its entry cell now, so sitting on that cell is a collision already
    now = (state.t + 1 >= state.enter_t) & (prog < cfg.size)
    pos = positions(nstate, cfg)
    # cell id per car; off-road cars get a unique sentinel so they never match
    cell = pos[:, 0] * cfg.size + pos[:, 1]
    cell = jnp.where(now, cell, cfg.size * cfg.size + jnp.arange(cfg.n_agents))
    share = jnp.sum(cell[:, None] == cell[None, :], axis=1) - 1
    coll = share > 0                                         # (A,) bool
    tau = (state.t + 1 - state.enter_t).astype(jnp.float32)
    rewards = jnp.where(
        now,
        cfg.time_penalty * tau
        + cfg.collision_penalty * coll.astype(jnp.float32),
        0.0)
    t = state.t + 1
    cleared = jnp.all(prog >= cfg.size)
    done = cleared | (t >= cfg.max_steps)
    return EnvState(route=state.route, enter_t=state.enter_t, prog=prog,
                    collided=state.collided | jnp.any(coll),
                    cleared=cleared, t=t), \
        rewards, done


def success(state: EnvState) -> jax.Array:
    # no collision AND every car cleared the grid — an all-brake policy
    # that just waits out the episode does not count as a success
    return ~state.collided & state.cleared


# ---------------------------------------------------------------------------
# Hard variant — IC3Net's harder TJ regime: bigger grid, more cars, and a
# dense Bernoulli(p_arrive) arrival stream instead of one-car-per-step
# staggering, so several cars contest the junction at once.
# ---------------------------------------------------------------------------

class HardConfig(NamedTuple):
    n_agents: int = 10
    size: int = 11
    vision: int = 1
    max_steps: int = 60
    time_penalty: float = -0.01
    collision_penalty: float = -1.0
    p_arrive: float = 0.7             # per-step arrival probability


def arrival_stream(key: jax.Array, n: int, p_arrive: float,
                   cap: int) -> jax.Array:
    """(n,) strictly-increasing entry steps with Geometric(p) gaps.

    Entry gaps drawn Geometric(p_arrive): the i-th car enters one gap
    after the (i-1)-th, so a higher ``p_arrive`` packs more cars onto the
    road simultaneously. Entries stay *strictly increasing* even when the
    tail is squeezed under the feasibility budget ``cap`` (the latest
    step from which the last car can still clear before ``max_steps``) —
    two cars must never share an entry step, or same-route pairs would
    spawn collided and no policy could succeed (collisions have to come
    from policy, as in the easy env). Shared by the hard and 4-way
    variants.
    """
    p = min(max(p_arrive, 1e-3), 1.0)
    if p >= 1.0:
        gaps = jnp.ones((n,), jnp.int32)
    else:
        u = jax.random.uniform(key, (n,), minval=1e-6, maxval=1.0)
        gaps = 1 + jnp.floor(jnp.log(u) / jnp.log1p(-p)).astype(jnp.int32)
    enter_t = jnp.cumsum(gaps) - gaps[0]                 # first car at t=0
    # squeeze the tail under the feasibility budget while keeping entries
    # strictly increasing: car i may enter no later than cap - (n-1-i),
    # and (fallback when even that is infeasible) no earlier than i
    cap = max(0, cap)
    idx = jnp.arange(n)
    enter_t = jnp.maximum(idx, jnp.minimum(enter_t, cap - (n - 1 - idx)))
    return enter_t.astype(jnp.int32)


def reset_hard(key: jax.Array, cfg: HardConfig) -> EnvState:
    """Hard-variant reset: Geometric(p_arrive) arrival stream (see
    :func:`arrival_stream`) over the two straight routes."""
    kr, ke = jax.random.split(key)
    a = cfg.n_agents
    route = jax.random.bernoulli(kr, 0.5, (a,)).astype(jnp.int32)
    enter_t = arrival_stream(ke, a, cfg.p_arrive,
                             cfg.max_steps - cfg.size - 1)
    return EnvState(route=route, enter_t=enter_t,
                    prog=jnp.zeros((a,), jnp.int32),
                    collided=jnp.zeros((), bool),
                    cleared=jnp.zeros((), bool),
                    t=jnp.zeros((), jnp.int32))
