"""Async actor/learner MARL pipeline — IMPALA-style decoupled training.

The synchronous engine (``repro.marl.train``) fuses rollout and learning
into one ``lax.scan``: the learner idles while actors step environments
and vice versa — the serialization the LearningGroup paper removes
on-chip with its overlapped OSEL→core dataflow. This module splits the
two clocks:

* **actors** run :func:`repro.marl.train.rollout` (collect mode) against a
  *published* :class:`ParamBundle` snapshot and push whole rollout windows
  into a **device-resident trajectory queue** (:class:`TrajQueue`) — a
  fixed-capacity ring buffer whose jitted :func:`queue_push` /
  :func:`queue_pop` / :func:`queue_sample` keep the actor→learner handoff
  on device (the host only mirrors scalar metadata, never the tensors);
* the **learner** drains queue windows at its own cadence, re-unrolls the
  policy over the stored trajectory (:func:`replay_terms` — the same
  per-step ops as the rollout, via ``train._policy_terms``) and applies
  the A2C update extended with an **off-policy correction**
  (``AsyncConfig.correction``): ``"vtrace"`` (IMPALA), ``"clip"``
  (one-sided clipped importance weights) or ``"none"`` (the pure
  on-policy update — with queue depth 1 it is bitwise-identical to the
  synchronous scan, the anchor the tests pin);
* every ``publish_every`` updates the learner **publishes** a versioned
  ``(params, PlanState, plan_signature)`` bundle. Publication certifies
  the plans against the params via ``encoder.refresh_if_stale`` — exactly
  the request-boundary gate ``ServeSession`` uses — so actors can never
  step on a params/plans mismatch; :func:`adopt` re-certifies on the
  actor side as a belt-and-suspenders swap gate.

Staleness is bounded: every queue window is stamped with the version of
the bundle that generated it, and the learner skips windows older than
``max_staleness`` publications. At staleness 0 the V-trace targets
provably collapse to the synchronous Monte-Carlo returns (clips ≥ 1 make
every importance ratio exactly 1, and the V-terms telescope away), so the
correction costs nothing while the pipeline is effectively on-policy.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import encoder
from repro.marl import envs as envs_mod
from repro.marl import ic3net
from repro.marl import train as train_mod
from repro.optim.optimizers import rmsprop
from repro.sharding.partition import constrain

CORRECTIONS = ("none", "clip", "vtrace")
PUSH_POLICIES = ("overwrite", "drop")


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    """Knobs of the decoupled pipeline (rides beside ``TrainConfig``)."""
    capacity: int = 4             # trajectory-queue depth (rollout windows)
    actors: int = 1               # rollout windows generated per update
    correction: str = "vtrace"    # off-policy correction: none|clip|vtrace
    rho_clip: float = 1.0         # V-trace rho-bar / IS clip ceiling
    c_clip: float = 1.0           # V-trace c-bar (trace cutting)
    max_staleness: int = 8        # max version lag of a consumed window
    publish_every: int = 1        # learner updates per params publication
    push_policy: str = "overwrite"  # ring full: overwrite oldest | drop new
    sample: str = "fifo"          # learner consumption: fifo | random

    def __post_init__(self):
        if self.correction not in CORRECTIONS:
            raise ValueError(f"correction must be one of {CORRECTIONS}, "
                             f"got {self.correction!r}")
        if self.push_policy not in PUSH_POLICIES:
            raise ValueError(f"push_policy must be one of {PUSH_POLICIES}, "
                             f"got {self.push_policy!r}")
        if self.sample not in ("fifo", "random"):
            raise ValueError(f"sample must be fifo|random, "
                             f"got {self.sample!r}")
        if self.capacity < 1 or self.actors < 1 or self.publish_every < 1:
            raise ValueError("capacity, actors and publish_every must be "
                             ">= 1")


class Trajectory(NamedTuple):
    """One actor rollout window — everything the learner needs to replay.

    ``obs``/``act``/``gates`` let the learner re-unroll the policy with
    its own params (BPTT through the LSTM happens on the learner's
    re-forward, as in IMPALA); ``logp`` is the *behavior* log-prob used by
    the importance-ratio corrections; ``rew`` already carries the
    freeze-after-done zeroing the rollout applies.
    """
    obs: jax.Array      # (B, T, A, obs_dim) float32
    act: jax.Array      # (B, T, A) int32 sampled actions
    gates: jax.Array    # (B, T, A) float32 sampled comm gates (new_gate_t)
    rew: jax.Array      # (B, T, A) float32 rewards (post done-freeze)
    logp: jax.Array     # (B, T, A) float32 behavior log pi(act)
    succ: jax.Array     # (B,) bool episode success


# --------------------------------------------------------------------------
# Device-resident trajectory queue
# --------------------------------------------------------------------------

class TrajQueue(NamedTuple):
    """Fixed-capacity ring buffer of rollout windows, living on device.

    ``data`` holds every :class:`Trajectory` leaf with a leading capacity
    axis; ``version`` stamps the params publication each slot was
    generated under. ``head`` is the next write slot, ``count`` the number
    of valid entries — the oldest valid entry sits at ``(head - count)
    mod capacity``. All ops are jittable with static shapes, so pushes
    and pops move zero trajectory bytes through host Python.
    """
    data: Any           # pytree of (C, ...) arrays
    version: jax.Array  # (C,) int32
    head: jax.Array     # () int32 — next write index, always < C
    count: jax.Array    # () int32 — number of valid entries
    pushed: jax.Array   # () int32 — accepted pushes (lifetime)
    dropped: jax.Array  # () int32 — rejected pushes (push_policy="drop")

    @property
    def capacity(self) -> int:
        return self.version.shape[0]


def queue_init(capacity: int, example) -> TrajQueue:
    """Empty queue whose slots are shaped like ``example`` (an abstract
    ``ShapeDtypeStruct`` tree from ``jax.eval_shape`` or a concrete
    trajectory)."""
    data = jax.tree.map(
        lambda x: jnp.zeros((capacity,) + tuple(x.shape), x.dtype), example)
    z = jnp.zeros((), jnp.int32)
    return TrajQueue(data=data,
                     version=jnp.zeros((capacity,), jnp.int32),
                     head=z, count=z, pushed=z, dropped=z)


@partial(jax.jit, static_argnames=("policy",))
def queue_push(q: TrajQueue, item, version,
               policy: str = "overwrite") -> TrajQueue:
    """Push one window. Ring full: ``"overwrite"`` replaces the oldest
    entry (head == oldest when full), ``"drop"`` rejects the new one."""
    cap = q.capacity
    version = jnp.asarray(version, jnp.int32)
    if policy == "drop":
        accept = q.count < cap

        def wr(buf, x):
            return jnp.where(accept, buf.at[q.head].set(x), buf)
        data = jax.tree.map(wr, q.data, item)
        vers = jnp.where(accept, q.version.at[q.head].set(version),
                         q.version)
        step = accept.astype(jnp.int32)
        return q._replace(
            data=data, version=vers,
            head=(q.head + step) % cap,
            count=q.count + step,
            pushed=q.pushed + step,
            dropped=q.dropped + (1 - step))
    data = jax.tree.map(lambda buf, x: buf.at[q.head].set(x), q.data, item)
    return q._replace(
        data=data, version=q.version.at[q.head].set(version),
        head=(q.head + 1) % cap,
        count=jnp.minimum(q.count + 1, cap),
        pushed=q.pushed + 1)


@jax.jit
def queue_pop(q: TrajQueue):
    """FIFO: return ``(item, version, q')`` for the oldest valid entry.

    Popping an empty queue is a host-side contract violation (the host
    mirrors ``count``); the returned slot contents are then unspecified
    but ``count`` stays clamped at 0.
    """
    idx = (q.head - q.count) % q.capacity
    item = jax.tree.map(lambda buf: buf[idx], q.data)
    return item, q.version[idx], \
        q._replace(count=jnp.maximum(q.count - 1, 0))


@jax.jit
def queue_sample(q: TrajQueue, key):
    """Uniform sample over the valid entries (without consuming):
    ``(item, version)``. Deterministic under a fixed key."""
    j = jax.random.randint(key, (), 0, jnp.maximum(q.count, 1))
    idx = (q.head - q.count + j) % q.capacity
    return jax.tree.map(lambda buf: buf[idx], q.data), q.version[idx]


# --------------------------------------------------------------------------
# Versioned params publication
# --------------------------------------------------------------------------

class ParamBundle(NamedTuple):
    """What the learner publishes and actors consume: a params snapshot,
    the PlanState encoded from it, and a monotonically increasing version.
    The invariant — ``plans.sig == plan_signature(params)`` whenever plans
    are non-empty — is established by :func:`publish` and re-checked by
    :func:`adopt`, so an actor can never run grouped kernels against
    metadata of weights that no longer exist."""
    params: Any
    plans: Any          # encoder.PlanState (empty off the grouped path)
    version: jax.Array  # () int32


def publish(params, plans, version, cfg: ic3net.IC3NetConfig) -> ParamBundle:
    """Stamp a new bundle, certifying plans against params.

    The learner's plans may be stale relative to its just-updated params
    (the refresh schedule amortizes encodes); publication is a boundary
    the staleness must not cross — ``encoder.refresh_if_stale`` re-encodes
    iff the grouping layout moved, exactly like ``ServeSession`` certifies
    at request boundaries. Traceable (``lax.cond`` inside).
    """
    if isinstance(plans, encoder.PlanState) and plans.plans:
        plans = encoder.refresh_if_stale(params, plans, cfg.flgw)
    return ParamBundle(params, plans, jnp.asarray(version, jnp.int32))


def adopt(bundle: ParamBundle, cfg: ic3net.IC3NetConfig) -> ParamBundle:
    """Actor-side swap gate: certify the incoming bundle before stepping.

    :func:`publish` already guarantees consistency, but the actor is the
    party that pays for a violation (grouped projections against foreign
    metadata), so the swap re-runs the same signature-gated certification
    — one ~half-encode signature pass when consistent, one re-encode when
    not. This is the guard ``test_adopt_heals_a_mismatched_bundle`` and
    the trace-count tests pin.
    """
    if isinstance(bundle.plans, encoder.PlanState) and bundle.plans.plans:
        plans = encoder.refresh_if_stale(bundle.params, bundle.plans,
                                         cfg.flgw)
        return bundle._replace(plans=plans)
    return bundle


def bundle_consistent(bundle: ParamBundle) -> jax.Array:
    """Bool scalar: do the bundle's plans certify against its params?
    (Trivially true off the grouped path.) Host-checkable guard used by
    the pipeline's paranoid mode and the publication tests."""
    if not (isinstance(bundle.plans, encoder.PlanState)
            and bundle.plans.plans):
        return jnp.ones((), bool)
    return encoder.plan_signature(bundle.params) == bundle.plans.sig


# --------------------------------------------------------------------------
# Actor and learner computations (both jitted once per config)
# --------------------------------------------------------------------------

def actor_rollout(params, key, cfg, ecfg, tcfg, env: envs_mod.Env,
                  plans=None) -> Trajectory:
    """One batched rollout window against a published snapshot.

    Key handling mirrors :func:`train.a2c_loss` exactly (same
    ``split(key, batch)``), so with queue depth 1 and ``correction=
    "none"`` the pipeline consumes the very same episodes the synchronous
    scan would have generated — the bitwise anchor.
    """
    keys = jax.random.split(key, tcfg.batch)
    keys = constrain(keys, ("env",) + (None,) * (keys.ndim - 1))
    rew, logp, val, ent, gate_logp, gates, obs, act, succ = jax.vmap(
        lambda k: train_mod.rollout(params, k, cfg, ecfg, env, plans,
                                    collect=True))(keys)
    del val, ent, gate_logp   # learner re-derives them from its own params
    return Trajectory(obs=obs, act=act, gates=gates, rew=rew, logp=logp,
                      succ=succ)


def replay_terms(params, cfg, traj: Trajectory, plans=None):
    """Re-unroll the policy over a stored trajectory with the *learner's*
    params: (logp, val, ent, gate_logp), each (B, T, A).

    Identical per-step math to the rollout (``train._policy_terms`` on
    the same ``policy_step`` forward), with the stored gate decisions
    replayed — ``gate_in[t] = gates[t-1]`` (ones at t=0, matching
    ``ic3net.initial_state``) — so at equal params the outputs are
    bitwise the rollout's and gradients see the same BPTT graph the
    synchronous loss differentiates.
    """
    gate_in = jnp.concatenate(
        [jnp.ones_like(traj.gates[:, :1]), traj.gates[:, :-1]], axis=1)

    def one_env(obs_seq, act_seq, gin_seq, gout_seq):
        hc, _ = ic3net.initial_state(cfg)

        def step(hc, inp):
            obs, act, gin, gout = inp
            logits, value, gate_logits, hc = ic3net.policy_step(
                params, cfg, obs, hc, gin, plans)
            logp_a, entropy, gate_logp = train_mod._policy_terms(
                logits, gate_logits, act, gout)
            return hc, (logp_a, value, entropy, gate_logp)

        _, outs = jax.lax.scan(step, hc,
                               (obs_seq, act_seq, gin_seq, gout_seq))
        return outs

    logp, val, ent, gate_logp = jax.vmap(one_env)(
        traj.obs, traj.act, gate_in, traj.gates)
    logp, val, ent = (constrain(t, ("env", None, "agent"))
                      for t in (logp, val, ent))
    return logp, val, ent, gate_logp


def vtrace(target_logp, behavior_logp, rew, val, *, gamma: float,
           rho_clip: float = 1.0, c_clip: float = 1.0):
    """V-trace targets (Espeholt et al. '18) over (B, T, A) tensors.

    Bootstraps with V_T = 0 — the episodes are fixed-length windows whose
    rewards are zeroed after ``done`` (the rollout's freeze), which is
    exactly the regime where the synchronous loss's Monte-Carlo returns
    terminate at zero. Hence at staleness 0 (ratios exactly 1, clips
    >= 1) the recursion telescopes to those MC returns:
    ``vs_t = r_t + gamma * vs_{t+1}`` and ``pg_adv = returns - val`` —
    the on-policy update, provably.

    Returns ``(vs, pg_adv, rho)``; gradients are *not* stopped here (the
    caller stops them — the loss needs ``val`` live elsewhere).
    """
    ratio = jnp.exp(target_logp - behavior_logp)
    rho = jnp.minimum(ratio, rho_clip)
    c = jnp.minimum(ratio, c_clip)
    v_next = jnp.concatenate([val[:, 1:], jnp.zeros_like(val[:, :1])], 1)
    delta = rho * (rew + gamma * v_next - val)

    def back(acc, xs):
        d, c_t = xs
        acc = d + gamma * c_t * acc
        return acc, acc

    _, err = jax.lax.scan(
        back, jnp.zeros_like(val[:, 0]),
        (delta[:, ::-1].swapaxes(0, 1), c[:, ::-1].swapaxes(0, 1)))
    err = err[::-1].swapaxes(0, 1)            # vs_t - V_t, (B, T, A)
    vs = err + val
    vs_next = jnp.concatenate([vs[:, 1:], jnp.zeros_like(vs[:, :1])], 1)
    pg_adv = rho * (rew + gamma * vs_next - val)
    return vs, pg_adv, rho


def learner_loss(params, traj: Trajectory, cfg, tcfg, acfg: AsyncConfig,
                 plans=None):
    """Loss of one consumed window under ``acfg.correction``.

    ``"none"`` routes the replayed terms through the *same*
    :func:`train.a2c_terms` the synchronous path uses — zero loss-math
    divergence, the bitwise anchor. ``"vtrace"`` swaps the MC returns for
    V-trace targets; ``"clip"`` keeps MC returns but scales the policy
    gradient by one-sided clipped importance weights.
    """
    logp, val, ent, gate_logp = replay_terms(params, cfg, traj, plans)
    if acfg.correction == "none":
        return train_mod.a2c_terms(traj.rew, logp, val, ent, gate_logp,
                                   traj.gates, traj.succ, tcfg)

    if acfg.correction == "vtrace":
        vs, pg_adv, rho = vtrace(logp, traj.logp, traj.rew, val,
                                 gamma=tcfg.gamma, rho_clip=acfg.rho_clip,
                                 c_clip=acfg.c_clip)
        pg = -jnp.mean(logp * jax.lax.stop_gradient(pg_adv))
        vloss = jnp.mean((jax.lax.stop_gradient(vs) - val) ** 2)
        mean_is = jnp.mean(rho)
    else:                                     # "clip"
        def disc(carry, r):
            carry = r + tcfg.gamma * carry
            return carry, carry
        _, returns = jax.lax.scan(disc, jnp.zeros_like(traj.rew[:, 0]),
                                  traj.rew[:, ::-1].swapaxes(0, 1))
        returns = returns[::-1].swapaxes(0, 1)
        adv = returns - val
        rho = jnp.minimum(jnp.exp(logp - traj.logp), acfg.rho_clip)
        pg = -jnp.mean(logp * jax.lax.stop_gradient(rho * adv))
        vloss = jnp.mean(adv ** 2)
        mean_is = jnp.mean(rho)
    eloss = -jnp.mean(ent)
    gloss = jnp.mean(traj.gates)
    loss = pg + tcfg.value_coef * vloss + tcfg.entropy_coef * eloss \
        + tcfg.gate_coef * gloss
    return loss, {"success": jnp.mean(traj.succ.astype(jnp.float32)),
                  "return": jnp.mean(jnp.sum(traj.rew, axis=1)),
                  "loss": loss, "mean_is": mean_is}


def learner_update(params, opt_state, traj: Trajectory, cfg, tcfg,
                   acfg: AsyncConfig, plans=None):
    """(params', opt_state', metrics) — one learner step on one window."""
    (_, metrics), grads = jax.value_and_grad(
        learner_loss, has_aux=True)(params, traj, cfg, tcfg, acfg, plans)
    metrics = dict(metrics,
                   mask_sparsity=train_mod._mean_mask_sparsity(params, cfg))
    params, opt_state = rmsprop(params, grads, opt_state, lr=tcfg.lr)
    return params, opt_state, metrics


# --------------------------------------------------------------------------
# The pipeline driver
# --------------------------------------------------------------------------

# module-level jits: one compile cache shared by every async_train call
# (the sync path's _train_chunk gets the same treatment in train.py)
_jit_actor = partial(jax.jit, static_argnames=("cfg", "ecfg", "tcfg",
                                               "env"))(actor_rollout)
_jit_update = partial(jax.jit, static_argnames=("cfg", "tcfg",
                                                "acfg"))(learner_update)
_jit_publish = partial(jax.jit, static_argnames=("cfg",))(publish)


class QueueDriver:
    """Host-side handle on the device queue: jitted push/pop plus a scalar
    metadata mirror (count + per-slot versions), so staleness decisions
    never force a device sync. Thread-safe — the threaded pipeline's
    actor and learner share one driver under ``lock``.
    """

    def __init__(self, capacity: int, example, push_policy: str):
        self.q = queue_init(capacity, example)
        self.push_policy = push_policy
        self.versions: list[int] = []          # oldest first
        self.lock = threading.Lock()

    def __len__(self) -> int:
        return len(self.versions)

    def push(self, traj: Trajectory, version: int) -> bool:
        with self.lock:
            if (self.push_policy == "drop"
                    and len(self.versions) >= self.q.capacity):
                self.q = queue_push(self.q, traj, version,
                                    policy=self.push_policy)
                return False
            self.q = queue_push(self.q, traj, version,
                                policy=self.push_policy)
            self.versions.append(version)
            if len(self.versions) > self.q.capacity:   # overwrote oldest
                self.versions.pop(0)
            return True

    def pop(self):
        with self.lock:
            if not self.versions:
                raise IndexError("pop from an empty trajectory queue")
            traj, ver, self.q = queue_pop(self.q)
            return traj, self.versions.pop(0)

    def sample(self, key):
        with self.lock:
            if not self.versions:
                raise IndexError("sample from an empty trajectory queue")
            traj, ver = queue_sample(self.q, key)
            return traj, ver

    def peek_version(self) -> int:
        """Version stamp of the oldest entry (host mirror, no device op)."""
        with self.lock:
            if not self.versions:
                raise IndexError("peek on an empty trajectory queue")
            return self.versions[0]


def _history_entry(metrics, *, staleness, depth) -> dict:
    ms = {k: float(v) for k, v in metrics.items()}
    ms["staleness"] = float(staleness)
    ms["queue_depth"] = float(depth)
    return ms


def async_train(cfg: ic3net.IC3NetConfig, ecfg=None,
                tcfg: train_mod.TrainConfig = None,
                acfg: AsyncConfig = None, updates: int = 100,
                seed: int = 0, log_every: int = 0,
                env: str | envs_mod.Env = "predator_prey",
                schedule=None, threads: bool = False,
                check_publication: bool = False,
                debug_contracts: bool = False):
    """Run the decoupled pipeline for ``updates`` learner steps.

    Returns ``(params, history)`` like :func:`train.train`; each history
    entry additionally carries ``staleness`` (version lag of the consumed
    window), ``queue_depth``, ``mean_is`` (corrections only) and the
    decoupled throughput pair — ``env_steps_per_s`` counts *generated*
    env steps (the actor clock), ``updates_per_s`` the learner clock.

    The default driver interleaves deterministically (``acfg.actors``
    pushes, then one learner step — reproducible, and with depth 1 +
    ``correction="none"`` bitwise-equal to the sync scan); ``threads=
    True`` runs the actor on its own Python thread for real dispatch
    overlap, at the cost of a nondeterministic interleaving.

    ``schedule.warmup_steps`` (the dense G-ramp) is a synchronous-loop
    feature — the published snapshot would need a per-version ramp state
    — and is rejected here; run the warmup synchronously, then hand the
    params to the async pipeline.

    ``debug_contracts=True`` runs the whole pipeline under
    :func:`repro.analysis.contracts.no_retrace`: the actor rollout,
    learner update and publication step may each compile once; any
    mid-run recompile (shape instability, a traced flag) raises
    :class:`~repro.analysis.contracts.RetraceError` — on either thread,
    since jax's compile log is process-global.
    """
    if debug_contracts:
        from repro.analysis import contracts
        with contracts.no_retrace(label="async_train"):
            return async_train(
                cfg, ecfg, tcfg, acfg, updates=updates, seed=seed,
                log_every=log_every, env=env, schedule=schedule,
                threads=threads, check_publication=check_publication,
                debug_contracts=False)
    if isinstance(env, str):
        env = envs_mod.get(env)
    if ecfg is None:
        ecfg = env.config_cls()
    tcfg = tcfg or train_mod.TrainConfig()
    acfg = acfg or AsyncConfig()
    if schedule is not None and schedule.warmup_steps > 0:
        raise NotImplementedError(
            "async_train does not run the dense-warmup G-ramp; warm up "
            "with train.train(...) first, then continue async")
    cfg, key, params, opt_state = train_mod._init(cfg, ecfg, env, seed)
    plans = train_mod._encode_plans(params, cfg)
    jit_actor, jit_update, jit_publish = _jit_actor, _jit_update, _jit_publish

    version = 0
    bundle = jit_publish(params, plans, version, cfg)
    if check_publication:
        assert bool(bundle_consistent(bundle)), \
            "publication produced a params/PlanState signature mismatch"
    example = jax.eval_shape(
        lambda p, k, pl: actor_rollout(p, k, cfg, ecfg, tcfg, env, pl),
        params, key, bundle.plans)
    queue = QueueDriver(acfg.capacity, example, acfg.push_policy)

    history: list[dict] = []
    pending: list = []    # (device metrics, staleness, depth) per update

    def flush_history():
        """Materialize every pending update's metrics in one host fetch
        (the marl scan's once-per-window discipline — the learner loop
        itself never blocks on metric values)."""
        if pending:
            fetched = jax.device_get([m for m, _, _ in pending])  # 1 sync
            for host_m, (_, stale, depth) in zip(fetched, pending):
                history.append(
                    _history_entry(host_m, staleness=stale, depth=depth))
            pending.clear()

    env_steps_window = tcfg.batch * ecfg.max_steps
    produced = {"windows": 0}
    stop = threading.Event()
    publish_lock = threading.Lock()

    def one_actor_push(k):
        b = bundle            # snapshot reference (publication swaps it)
        traj = jit_actor(b.params, k, cfg, ecfg, tcfg, env, b.plans)
        queue.push(traj, int(b.version))
        produced["windows"] += 1

    actor_thread = None
    if threads:
        akey = jax.random.fold_in(key, 0x5eed)

        def actor_loop():
            nonlocal akey
            while not stop.is_set():
                if len(queue) >= acfg.capacity \
                        and acfg.push_policy == "drop":
                    time.sleep(0)             # yield; learner will drain
                    continue
                akey, k = jax.random.split(akey)
                with publish_lock:
                    one_actor_push(k)

        actor_thread = threading.Thread(target=actor_loop, daemon=True)

    t0 = time.perf_counter()
    if actor_thread:
        actor_thread.start()
    try:
        for it in range(updates):
            if not threads:
                for _ in range(acfg.actors):
                    key, k = jax.random.split(key)
                    one_actor_push(k)
            else:
                while not len(queue):         # wait for the actor clock
                    time.sleep(0)
            # learner: staleness bound first — evict windows over it (the
            # host version mirror decides; versions are nondecreasing in
            # FIFO order, so draining the front leaves only fresh entries)
            while len(queue) \
                    and version - queue.peek_version() > acfg.max_staleness:
                queue.pop()
            traj = ver = None
            if len(queue):
                if acfg.sample == "random":
                    key, k = jax.random.split(key)
                    traj, ver = queue.sample(k)
                else:
                    traj, ver = queue.pop()
            if traj is None:
                # everything in flight was over the bound — generate an
                # on-policy window so the learner never starves
                key, k = jax.random.split(key)
                with publish_lock:
                    bundle = jit_publish(params, plans, version, cfg)
                    one_actor_push(k)
                traj, ver = queue.pop()
            plans = train_mod._refresh_plans(params, plans, it, cfg=cfg,
                                             schedule=schedule)
            params, opt_state, metrics = jit_update(
                params, opt_state, traj, cfg, tcfg, acfg, plans)
            version += 1
            if version % acfg.publish_every == 0:
                with publish_lock:
                    bundle = jit_publish(params, plans, version, cfg)
                if check_publication:
                    assert bool(bundle_consistent(bundle)), \
                        "published params/PlanState signature mismatch " \
                        f"at version {version}"
            pending.append((metrics, version - 1 - ver, len(queue)))
            if log_every and it % log_every == 0:
                flush_history()    # log boundary: one batched fetch
                print(f"update {it:5d} success "
                      f"{history[-1]['success']:.3f} return "
                      f"{history[-1]['return']:.3f} staleness "
                      f"{history[-1]['staleness']:.0f}")
    finally:
        stop.set()
        if actor_thread:
            actor_thread.join(timeout=30)
        flush_history()
    dt = max(time.perf_counter() - t0, 1e-9)
    env_rate = produced["windows"] * env_steps_window / dt
    upd_rate = updates / dt
    for ms in history:
        ms["env_steps_per_s"] = env_rate
        ms["updates_per_s"] = upd_rate
        ms["steps_per_s"] = upd_rate          # sync-history compatibility
    return params, history
