"""Back-compat shim — Predator-Prey lives in ``repro.marl.envs``.

The single-environment module grew into the ``repro.marl.envs`` subpackage
(registry + Predator-Prey, Traffic Junction, Spread). Importing
``repro.marl.env`` keeps resolving to the Predator-Prey functions so seed
code and tests keep working; new code should go through
``repro.marl.envs.get(name)``.
"""
from repro.marl.envs.predator_prey import (  # noqa: F401
    _MOVES,
    N_ACTIONS,
    EnvConfig,
    EnvState,
    n_actions,
    obs_dim,
    observe,
    reset,
    step,
    success,
)
