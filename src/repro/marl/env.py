"""Back-compat shim — Predator-Prey lives in ``repro.marl.envs``.

The single-environment module grew into the ``repro.marl.envs`` subpackage
(registry + Predator-Prey, Traffic Junction, Spread). Importing
``repro.marl.env`` keeps resolving to the Predator-Prey functions so seed
code and tests keep working; new code should go through
``repro.marl.envs.get(name)``.
"""
import warnings

warnings.warn(
    "repro.marl.env is a back-compat shim; use repro.marl.envs "
    "(e.g. repro.marl.envs.get('predator_prey')) instead.",
    DeprecationWarning, stacklevel=2)

from repro.marl.envs.predator_prey import (  # noqa: E402,F401
    _MOVES,
    N_ACTIONS,
    EnvConfig,
    EnvState,
    n_actions,
    obs_dim,
    observe,
    reset,
    step,
    success,
)
