"""LearningGroup reproduction — FLGW sparse training on JAX/Pallas."""
