"""Deterministic, host-sharded synthetic token pipeline.

Replay-exact by construction: the batch at step ``s`` is a pure function of
``(seed, s, host_shard)`` — after a preemption/restart the pipeline resumes
from the checkpointed step with bit-identical data, no input-state
checkpoint needed. Each host generates only its shard of the global batch
(``jax.make_array_from_callback`` assembles the global array), so the input
path scales to any host count without a central dispenser.

A background thread prefetches ``prefetch`` steps ahead so host-side
generation overlaps device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding


class SyntheticTokens:
    """LM token batches: (tokens, targets, positions) of (B, S) int32.

    A light Markov-ish structure (mixed-congruential walk over the vocab)
    rather than iid uniform, so losses move during smoke training.
    """

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed

    def batch_at(self, step: int, lo: int = 0, hi: Optional[int] = None
                 ) -> dict:
        """Rows [lo, hi) of the global batch at ``step`` (host shard)."""
        hi = self.batch if hi is None else hi
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, lo, hi]))
        n = hi - lo
        start = rng.integers(0, self.vocab, (n, 1), np.int64)
        stride = rng.integers(1, 7, (n, 1), np.int64)
        idx = np.arange(self.seq + 1, dtype=np.int64)[None, :]
        walk = (start + stride * idx + (idx * idx) // 7) % self.vocab
        toks = walk.astype(np.int32)
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "positions": np.broadcast_to(
                np.arange(self.seq, dtype=np.int32), (n, self.seq)).copy(),
        }

    def global_batch_at(self, step: int, sharding: Optional[dict] = None
                        ) -> dict:
        """Assemble the global (B, S) arrays, generating only local shards.

        ``sharding``: dict of NamedSharding per field (or None -> host
        arrays). Generation happens per device shard via the callback, so a
        multi-host launch materializes only local rows.
        """
        if sharding is None:
            return self.batch_at(step)

        def field(name, shard):
            shape = (self.batch, self.seq)

            def cb(index):
                rows = index[0]
                lo = rows.start or 0
                hi = rows.stop if rows.stop is not None else self.batch
                return self.batch_at(step, lo, hi)[name]

            return jax.make_array_from_callback(shape, shard, cb)

        return {name: field(name, sh) for name, sh in sharding.items()}


def make_batch_iterator(ds: SyntheticTokens, *, start_step: int = 0,
                        sharding: Optional[dict] = None,
                        prefetch: int = 2) -> Iterator[dict]:
    """Prefetching iterator over steps, resumable at ``start_step``."""
    q: queue.Queue = queue.Queue(maxsize=max(1, prefetch))
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            q.put(ds.global_batch_at(step, sharding))
            step += 1

    t = threading.Thread(target=producer, daemon=True)
    t.start()

    def gen():
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

    return gen()
