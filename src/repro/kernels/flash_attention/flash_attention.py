"""Pallas TPU flash attention: fused online-softmax attention, fwd + bwd.

The dry-run roofline shows every attention cell is MEMORY-bound because the
(S, T) logit matrix materializes in HBM (write + multi-pass softmax reads,
then again under remat). This kernel keeps the logits in VMEM tiles and
streams K/V blocks through the MXU — the standard TPU adaptation of
FlashAttention, extended with the features our architectures need:

  * GQA: q-head h reads kv-head h // qpk via the BlockSpec index map —
    no materialized KV repeat.
  * causal + sliding-window masking by absolute position, with whole-block
    skipping (a fully-masked (bq, bk) tile never touches the MXU);
  * gemma-style attention-logit softcap (tanh), handled exactly in bwd;
  * f32 accumulation, bf16/f32 operands.

Layouts: q (B, Hq, S, D), k/v (B, Hkv, T, D), out (B, Hq, S, D).
Backward is the standard two-pass scheme: a dq pass (grid over q blocks,
stream k) and a dkv pass (grid over k blocks, stream q), both recomputing
p from the saved logsumexp — nothing quadratic is ever stored.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params

NEG_INF = -2.3819763e38


def _apply_softcap(z, softcap):
    if softcap > 0:
        return jnp.tanh(z / softcap) * softcap
    return z


def _block_mask(iq, ik, bq, bk, *, causal, window):
    """(bq, bk) bool tile of allowed positions for blocks (iq, ik)."""
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    allowed = jnp.ones((bq, bk), bool)
    if causal:
        allowed &= kpos <= qpos
    if window > 0:
        allowed &= kpos > qpos - window
    return allowed


def _block_live(iq, ik, bq, bk, *, causal, window):
    """Whether block (iq, ik) has ANY unmasked entry (python-traced scalar)."""
    live = jnp.array(True)
    if causal:
        live &= (ik * bk) <= (iq * bq + bq - 1)
    if window > 0:
        live &= (ik * bk + bk - 1) > (iq * bq - window)
    return live


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
                *, scale, causal, window, softcap, nk):
    iq, ik = pl.program_id(2), pl.program_id(3)
    bq = q_ref.shape[2]
    bk = k_ref.shape[2]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(_block_live(iq, ik, bq, bk, causal=causal, window=window))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, D)
        z = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        z = _apply_softcap(z, softcap)
        mask = _block_mask(iq, ik, bq, bk, causal=causal, window=window)
        z = jnp.where(mask, z, NEG_INF)

        m_prev = m_ref[:, 0]                           # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(z, axis=1))
        alpha = jnp.exp(m_prev - m_new)                # (bq,)
        p = jnp.exp(z - m_new[:, None])                # (bq, bk)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        m_ref[:, 0] = m_new
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, D)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(ik == nk - 1)
    def _flush():
        l = l_ref[:, 0]
        l_safe = jnp.where(l == 0, 1.0, l)             # fully-masked rows
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = jnp.where(l == 0, NEG_INF, m_ref[:, 0] + jnp.log(l_safe))


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                              "bq", "bk", "interpret"))
def flash_fwd(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
              bq=512, bk=512, interpret=False):
    """Returns (out, lse). Shapes: q (B,Hq,S,D), k/v (B,Hkv,T,D)."""
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    qpk = hq // hkv
    scale = float(d ** -0.5) if scale is None else float(scale)
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    nq, nk = s // bq, t // bk

    kernel = functools.partial(_fwd_kernel, scale=scale, causal=causal,
                               window=window, softcap=softcap, nk=nk)
    out, lse = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, qpk=qpk: (b, h // qpk, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, qpk=qpk: (b, h // qpk, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, s), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Backward: dq pass (grid over q blocks, stream k) and dkv pass (grid over
# k blocks, stream q). p is recomputed from the saved lse.
# ---------------------------------------------------------------------------

def _recompute_p_dz(q, k, lse_blk, do, v, delta_blk, *, scale, softcap,
                    mask):
    """Shared bwd math for one (bq, bk) tile. Returns (p, dz)."""
    z_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
    z = _apply_softcap(z_raw, softcap)
    z = jnp.where(mask, z, NEG_INF)
    p = jnp.exp(z - lse_blk[:, None])                   # (bq, bk)
    p = jnp.where(mask, p, 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dz = p * (dp - delta_blk[:, None])                  # d logits (post-cap)
    if softcap > 0:
        dz = dz * (1.0 - jnp.square(jnp.tanh(z_raw / softcap)))
    return p, dz


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, causal, window, softcap, nk):
    iq, ik = pl.program_id(2), pl.program_id(3)
    bq, bk = q_ref.shape[2], k_ref.shape[2]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(_block_live(iq, ik, bq, bk, causal=causal, window=window))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        mask = _block_mask(iq, ik, bq, bk, causal=causal, window=window)
        _, dz = _recompute_p_dz(q, k, lse_ref[0, 0], do, v, delta_ref[0, 0],
                                scale=scale, softcap=softcap, mask=mask)
        acc_ref[...] += jax.lax.dot_general(
            dz, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(ik == nk - 1)
    def _flush():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal, window,
                softcap, nq, qpk):
    # grid: (B, Hkv, nk, qpk, nq) — for one kv block the (head-in-group,
    # q-block) accumulation dims are innermost, so the scratch accumulators
    # live exactly as long as one output block (consecutive revisits).
    ik, hg, iq = pl.program_id(2), pl.program_id(3), pl.program_id(4)
    bq, bk = q_ref.shape[2], k_ref.shape[2]

    @pl.when((iq == 0) & (hg == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(_block_live(iq, ik, bq, bk, causal=causal, window=window))
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        mask = _block_mask(iq, ik, bq, bk, causal=causal, window=window)
        p, dz = _recompute_p_dz(q, k, lse_ref[0, 0], do, v,
                                delta_ref[0, 0], scale=scale,
                                softcap=softcap, mask=mask)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dk_acc[...] += jax.lax.dot_general(
            dz, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when((iq == nq - 1) & (hg == qpk - 1))
    def _flush():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                              "bq", "bk", "interpret"))
def flash_bwd(q, k, v, out, lse, do, *, causal=True, window=0, softcap=0.0,
              scale=None, bq=512, bk=512, interpret=False):
    """Returns (dq, dk, dv)."""
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    qpk = hq // hkv
    scale = float(d ** -0.5) if scale is None else float(scale)
    bq = min(bq, s)
    bk = min(bk, t)
    # Same contract as flash_fwd. Without it, a caller passing a
    # non-dividing block silently drops the sequence tail: the grid is
    # floor(s/bq) × floor(t/bk), so dq/dk/dv tail tiles stay zero —
    # the coverage-gap class the static auditor
    # (repro.analysis.kernel_audit) checks for.
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    nq, nk = s // bq, t // bk

    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                            # (B, Hq, S)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, nk=nk),
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, qpk=qpk: (b, h // qpk, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, h, i, j, qpk=qpk: (b, h // qpk, j, 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, softcap=softcap, nq=nq, qpk=qpk),
        grid=(b, hkv, nk, qpk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d),
                         lambda b, g, j, hg, i, qpk=qpk:
                         (b, g * qpk + hg, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, g, j, hg, i: (b, g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, g, j, hg, i: (b, g, j, 0)),
            pl.BlockSpec((1, 1, bq, d),
                         lambda b, g, j, hg, i, qpk=qpk:
                         (b, g * qpk + hg, i, 0)),
            pl.BlockSpec((1, 1, bq),
                         lambda b, g, j, hg, i, qpk=qpk:
                         (b, g * qpk + hg, i)),
            pl.BlockSpec((1, 1, bq),
                         lambda b, g, j, hg, i, qpk=qpk:
                         (b, g * qpk + hg, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, d), lambda b, g, j, hg, i: (b, g, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, g, j, hg, i: (b, g, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, t, d), k.dtype),
            jax.ShapeDtypeStruct((b, hkv, t, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, d), jnp.float32),
            pltpu.VMEM((bk, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
