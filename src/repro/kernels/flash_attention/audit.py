"""KernelSpecs for the flash-attention kernels (jax-free).

Mirrors ``flash_attention.flash_fwd`` / ``flash_bwd``'s grids exactly as
the ``ops.py`` wrapper drives them (``pick_block`` divisor selection,
GQA ``h // qpk`` index maps), for the static auditor. Accumulation
declarations:

* fwd / bwd-dq: the k-block axis (grid axis 3) — online-softmax /
  dq accumulate in VMEM scratch and flush at the last k block;
* bwd-dkv: grid ``(B, Hkv, nk, qpk, nq)`` with the (head-in-group,
  q-block) axes 3 and 4 declared — one dk/dv tile is revisited
  ``qpk * nq`` times, and the revisits must be consecutive (both axes
  innermost), which is precisely what the disjointness check proves.
"""
from __future__ import annotations

from repro.analysis.kernel_audit import (GridCase, KernelSpec, Operand,
                                         register_kernel_spec)
from repro.kernels.tiling import pick_block

F32 = 4


def _blocks(p: dict):
    bq = min(pick_block(p["s"], p.get("bq", 512)), p["s"])
    bk = min(pick_block(p["t"], p.get("bk", 512)), p["t"])
    return bq, bk, p["s"] // bq, p["t"] // bk


def _label(p: dict) -> str:
    return (f"b{p['b']}_h{p['hq']}kv{p['hkv']}_s{p['s']}_t{p['t']}"
            f"_d{p['d']}")


def _tags(p: dict):
    return ("m_gt_4096",) if max(p["s"], p["t"]) > 4096 else ()


def _fwd_case(p: dict) -> GridCase:
    b, hq, hkv, d = p["b"], p["hq"], p["hkv"], p["d"]
    s, t = p["s"], p["t"]
    dt = p.get("itemsize", F32)
    qpk = hq // hkv
    bq, bk, nq, nk = _blocks(p)
    return GridCase(
        label=_label(p), grid=(b, hq, nq, nk),
        operands=(
            Operand("q", (b, hq, s, d), (1, 1, bq, d),
                    lambda bi, h, i, j: (bi, h, i, 0), dt),
            Operand("k", (b, hkv, t, d), (1, 1, bk, d),
                    lambda bi, h, i, j, qpk=qpk: (bi, h // qpk, j, 0),
                    dt),
            Operand("v", (b, hkv, t, d), (1, 1, bk, d),
                    lambda bi, h, i, j, qpk=qpk: (bi, h // qpk, j, 0),
                    dt),
            Operand("out", (b, hq, s, d), (1, 1, bq, d),
                    lambda bi, h, i, j: (bi, h, i, 0), dt, role="out"),
            Operand("lse", (b, hq, s), (1, 1, bq),
                    lambda bi, h, i, j: (bi, h, i), F32, role="out"),
        ),
        accum_axes=frozenset({3}),
        scratch_bytes=(bq * d + bq + bq) * F32,
        tags=_tags(p),
    )


def _dq_case(p: dict) -> GridCase:
    b, hq, hkv, d = p["b"], p["hq"], p["hkv"], p["d"]
    s, t = p["s"], p["t"]
    dt = p.get("itemsize", F32)
    qpk = hq // hkv
    bq, bk, nq, nk = _blocks(p)
    return GridCase(
        label=_label(p), grid=(b, hq, nq, nk),
        operands=(
            Operand("q", (b, hq, s, d), (1, 1, bq, d),
                    lambda bi, h, i, j: (bi, h, i, 0), dt),
            Operand("k", (b, hkv, t, d), (1, 1, bk, d),
                    lambda bi, h, i, j, qpk=qpk: (bi, h // qpk, j, 0),
                    dt),
            Operand("v", (b, hkv, t, d), (1, 1, bk, d),
                    lambda bi, h, i, j, qpk=qpk: (bi, h // qpk, j, 0),
                    dt),
            Operand("do", (b, hq, s, d), (1, 1, bq, d),
                    lambda bi, h, i, j: (bi, h, i, 0), dt),
            Operand("lse", (b, hq, s), (1, 1, bq),
                    lambda bi, h, i, j: (bi, h, i), F32),
            Operand("delta", (b, hq, s), (1, 1, bq),
                    lambda bi, h, i, j: (bi, h, i), F32),
            Operand("dq", (b, hq, s, d), (1, 1, bq, d),
                    lambda bi, h, i, j: (bi, h, i, 0), dt, role="out"),
        ),
        accum_axes=frozenset({3}),
        scratch_bytes=bq * d * F32,
        tags=_tags(p),
    )


def _dkv_case(p: dict) -> GridCase:
    b, hq, hkv, d = p["b"], p["hq"], p["hkv"], p["d"]
    s, t = p["s"], p["t"]
    dt = p.get("itemsize", F32)
    qpk = hq // hkv
    bq, bk, nq, nk = _blocks(p)
    return GridCase(
        label=_label(p), grid=(b, hkv, nk, qpk, nq),
        operands=(
            Operand("q", (b, hq, s, d), (1, 1, bq, d),
                    lambda bi, g, j, hg, i, qpk=qpk:
                    (bi, g * qpk + hg, i, 0), dt),
            Operand("k", (b, hkv, t, d), (1, 1, bk, d),
                    lambda bi, g, j, hg, i: (bi, g, j, 0), dt),
            Operand("v", (b, hkv, t, d), (1, 1, bk, d),
                    lambda bi, g, j, hg, i: (bi, g, j, 0), dt),
            Operand("do", (b, hq, s, d), (1, 1, bq, d),
                    lambda bi, g, j, hg, i, qpk=qpk:
                    (bi, g * qpk + hg, i, 0), dt),
            Operand("lse", (b, hq, s), (1, 1, bq),
                    lambda bi, g, j, hg, i, qpk=qpk:
                    (bi, g * qpk + hg, i), F32),
            Operand("delta", (b, hq, s), (1, 1, bq),
                    lambda bi, g, j, hg, i, qpk=qpk:
                    (bi, g * qpk + hg, i), F32),
            Operand("dk", (b, hkv, t, d), (1, 1, bk, d),
                    lambda bi, g, j, hg, i: (bi, g, j, 0), dt,
                    role="out"),
            Operand("dv", (b, hkv, t, d), (1, 1, bk, d),
                    lambda bi, g, j, hg, i: (bi, g, j, 0), dt,
                    role="out"),
        ),
        accum_axes=frozenset({3, 4}),
        scratch_bytes=2 * bk * d * F32,
        tags=_tags(p),
    )


_CORPUS = (
    {"b": 2, "hq": 8, "hkv": 2, "s": 1024, "t": 1024, "d": 64},  # GQA
    {"b": 1, "hq": 4, "hkv": 4, "s": 512, "t": 512, "d": 128,
     "itemsize": 2},                                      # MHA, bf16
    {"b": 1, "hq": 2, "hkv": 1, "s": 4352, "t": 4352, "d": 64},
    {"b": 2, "hq": 4, "hkv": 4, "s": 128, "t": 384, "d": 64},  # cross
)

register_kernel_spec(KernelSpec(
    name="flash_attention.flash_fwd",
    module="repro.kernels.flash_attention.flash_attention",
    build=_fwd_case, corpus=_CORPUS,
    note="online-softmax fwd; k-block axis accumulates",
))
register_kernel_spec(KernelSpec(
    name="flash_attention.flash_bwd_dq",
    module="repro.kernels.flash_attention.flash_attention",
    build=_dq_case, corpus=_CORPUS,
    note="bwd dq pass; k-block axis accumulates",
))
register_kernel_spec(KernelSpec(
    name="flash_attention.flash_bwd_dkv",
    module="repro.kernels.flash_attention.flash_attention",
    build=_dkv_case, corpus=_CORPUS,
    note="bwd dkv pass; (head-in-group, q-block) axes accumulate",
))
