"""Differentiable wrapper for the flash attention Pallas kernels.

``flash_attention(q, k, v, ...)`` is a drop-in fused replacement for the
materialized-logits attention core: custom_vjp wires the dq/dkv backward
kernels, so neither forward nor backward ever stores an (S, T) tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (flash_bwd,
                                                           flash_fwd)
from repro.kernels.flash_attention import ref as _ref
# Block selection is shared with the static auditor
# (repro.kernels.flash_attention.audit) so the audited grid is, by
# construction, the grid this wrapper builds.
from repro.kernels.tiling import pick_block as _pick_block


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention(q, k, v, causal=True, window=0, softcap=0.0,
                    scale=None, bq=512, bk=512, interpret=None):
    """q: (B, Hq, S, D); k/v: (B, Hkv, T, D) -> (B, Hq, S, D)."""
    out, _ = _fwd(q, k, v, causal, window, softcap, scale, bq, bk,
                  interpret)
    return out


def _fwd(q, k, v, causal, window, softcap, scale, bq, bk, interpret):
    if interpret is None:
        interpret = default_interpret()
    bq = _pick_block(q.shape[2], bq)
    bk = _pick_block(k.shape[2], bk)
    return flash_fwd(q, k, v, causal=causal, window=window,
                     softcap=softcap, scale=scale, bq=bq, bk=bk,
                     interpret=interpret)


def _flash_fwd_rule(q, k, v, causal, window, softcap, scale, bq, bk,
                    interpret):
    out, lse = _fwd(q, k, v, causal, window, softcap, scale, bq, bk,
                    interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, window, softcap, scale, bq, bk, interpret,
                    res, do):
    q, k, v, out, lse = res
    if interpret is None:
        interpret = default_interpret()
    bq_ = _pick_block(q.shape[2], bq)
    bk_ = _pick_block(k.shape[2], bk)
    dq, dk, dv = flash_bwd(q, k, v, out, lse, do, causal=causal,
                           window=window, softcap=softcap, scale=scale,
                           bq=bq_, bk=bk_, interpret=interpret)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def reference(q, k, v, *, causal=True, window=0, softcap=0.0, scale=None):
    return _ref.ref_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, scale=scale)
