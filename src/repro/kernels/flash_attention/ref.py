"""Pure-jnp oracle for the flash attention kernel.

Semantics shared with the kernel: GQA (q heads grouped onto kv heads),
causal and/or sliding-window masking by absolute positions starting at 0,
optional gemma-style attention-logit softcap, f32 softmax, output in the
query dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  softcap: float = 0.0, scale: float | None = None
                  ) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, T, D). Returns (B, Hq, S, D)."""
    b, hq, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    qpk = hq // hkv
    scale = d ** -0.5 if scale is None else scale
    qg = q.reshape(b, hkv, qpk, s, d)
    logits = jnp.einsum("bgqsd,bgtd->bgqst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    allowed = jnp.ones((s, t), bool)
    if causal:
        allowed &= kpos <= qpos
    if window > 0:
        allowed &= kpos > qpos - window
    logits = jnp.where(allowed, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgqst,bgtd->bgqsd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, s, d).astype(q.dtype)
