from repro.kernels.flgw_matmul.ops import grouped_matmul, reference  # noqa: F401
from repro.kernels.flgw_matmul.flgw_matmul import grouped_bmm  # noqa: F401
