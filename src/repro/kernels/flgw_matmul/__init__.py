# Lazy re-exports (PEP 562): importing the package must not pull in jax,
# so the jax-free audit module (audit.py / repro.analysis.kernel_audit)
# can load its KernelSpecs in the no-jax CI analysis job.
_EXPORTS = {
    "compact_weights": "ops", "grouped_matmul": "ops",
    "grouped_matmul_fused": "ops", "reference": "ops",
    "fused_bmm": "flgw_matmul", "grouped_bmm": "flgw_matmul",
}
__all__ = list(_EXPORTS)


def __getattr__(name):
    import importlib
    mod = _EXPORTS.get(name)
    if mod is not None:
        return getattr(
            importlib.import_module(f"{__name__}.{mod}"), name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
