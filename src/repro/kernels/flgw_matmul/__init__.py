from repro.kernels.flgw_matmul.ops import (compact_weights,  # noqa: F401
                                           grouped_matmul,
                                           grouped_matmul_fused, reference)
from repro.kernels.flgw_matmul.flgw_matmul import (fused_bmm,  # noqa: F401
                                                   grouped_bmm)
