"""Pallas TPU kernel: grouped (block-diagonal) batched matmul for FLGW.

This is the compute hot-spot of the LearningGroup accelerator, re-architected
for the TPU MXU. OSEL observation 2 says the FLGW mask consists of at most G
distinct row patterns, i.e. after a balanced group permutation the masked
matmul *is* G independent dense tiles:

    y_c[g] = x_c[g] @ W_c[g]          (G, B, capM) x (G, capM, capN)

The FPGA realizes this with 264-wide FP16 VPU rows and 2-bit activation mux
selects; the TPU-native equivalent is a dense batched matmul whose tiles are
MXU-aligned (multiples of 128 in the contracted/output dims) and staged
HBM→VMEM via BlockSpec. Compute drops by exactly G versus the dense layer.

Grid: (G, B/bb, capN/bn, capM/bk) with accumulation over the bk axis in an
f32 VMEM scratch accumulator.

``fused_bmm`` is the OSEL→core handoff variant: it consumes the ``(G, cap)``
compact format straight from the plan-encode output — the activation gather
``x -> x_c`` happens in the kernel prologue (a per-tile ``jnp.take`` against
the row-id tile) instead of as XLA VPU scatter/gather work, and the weight
side arrives already compacted (``W_c`` from the encode stage's
``compact_weights``). Invalid slots are routed to a zero column appended to
``x``, so the gather itself performs the masking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _bmm_kernel(xg_ref, wc_ref, out_ref, acc_ref, *, k_steps: int):
    """One (g, b-tile, n-tile, k-tile) grid step."""

    @pl.when(pl.program_id(3) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU matmul on the current VMEM tiles; accumulate in f32.
    acc_ref[...] += jax.lax.dot_general(
        xg_ref[0], wc_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _flush():
        out_ref[0, ...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "bn", "bk", "interpret"))
def grouped_bmm(xg: jax.Array, wc: jax.Array, *, bb: int = 128,
                bn: int = 128, bk: int = 128,
                interpret: bool = False) -> jax.Array:
    """(G, B, capM) @ (G, capM, capN) -> (G, B, capN).

    Dims must be multiples of the tile sizes (ops.py pads). Tile sizes default
    to 128 to align the MXU systolic array; the f32 accumulator tile is
    (bb, bn) in VMEM scratch. The per-step VMEM working set is audited
    statically over a shape corpus — see ``audit.py`` beside this module
    and ``python -m repro.analysis.kernel_audit`` for the numbers.
    """
    g, b, m = xg.shape
    g2, m2, n = wc.shape
    assert g == g2 and m == m2, (xg.shape, wc.shape)
    assert b % bb == 0 and n % bn == 0 and m % bk == 0, (xg.shape, wc.shape)
    k_steps = m // bk

    return pl.pallas_call(
        functools.partial(_bmm_kernel, k_steps=k_steps),
        grid=(g, b // bb, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((1, bb, bk), lambda g, i, j, k: (g, i, k)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bb, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, b, n), xg.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(xg, wc)


def _fused_kernel(x_ref, wc_ref, ids_ref, out_ref, acc_ref, *, k_steps: int):
    """One (g, b-tile, n-tile, k-tile) grid step with the x-gather fused
    into the prologue: the (bb, bk) compact activation tile is gathered
    from the full-width x block by this k-tile's row ids. Invalid slots
    hold ``m`` — the appended zero column — so the gather masks for free
    and the accumulated products match the XLA-gather path bitwise."""

    @pl.when(pl.program_id(3) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = ids_ref[0]                                     # (bk,) int32
    xt = jnp.take(x_ref[...], ids, axis=1)               # (bb, bk)
    acc_ref[...] += jax.lax.dot_general(
        xt, wc_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _flush():
        out_ref[0, ...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "bn", "bk", "interpret"))
def fused_bmm(x: jax.Array, wc: jax.Array, row_ids: jax.Array, *,
              bb: int = 128, bn: int = 128, bk: int = 128,
              interpret: bool = False) -> jax.Array:
    """(B, M+1) x, (G, capM, capN) wc, (G, capM) row ids -> (G, B, capN).

    ``x``'s last column must be zero (the invalid-slot sink: every padding
    or invalid ``row_ids`` entry must equal ``M``). ``B``/``capM``/``capN``
    must be multiples of the tile sizes (ops.py pads). The per-step VMEM
    working set is dominated by the (bb, M+1) activation block — the
    whole contracted width rides VMEM so the per-tile gather stays
    local; it is audited statically over a shape corpus, including the
    M > 4096 decode cases — see ``audit.py`` beside this module and
    ``python -m repro.analysis.kernel_audit`` for the numbers.
    """
    b, m1 = x.shape
    g, cap_m, n = wc.shape
    assert row_ids.shape == (g, cap_m), (row_ids.shape, wc.shape)
    assert b % bb == 0 and n % bn == 0 and cap_m % bk == 0, (x.shape,
                                                            wc.shape)
    k_steps = cap_m // bk

    return pl.pallas_call(
        functools.partial(_fused_kernel, k_steps=k_steps),
        grid=(g, b // bb, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bb, m1), lambda g, i, j, k: (i, 0)),
            pl.BlockSpec((1, bk, bn), lambda g, i, j, k: (g, k, j)),
            pl.BlockSpec((1, bk), lambda g, i, j, k: (g, k)),
        ],
        out_specs=pl.BlockSpec((1, bb, bn), lambda g, i, j, k: (g, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, b, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(x, wc, row_ids)
