"""KernelSpecs for the FLGW grouped-matmul kernels (jax-free).

Mirrors the exact grid/BlockSpec construction of
``flgw_matmul.grouped_bmm`` and ``flgw_matmul.fused_bmm`` as driven by
the ``ops.py`` wrappers (same :mod:`repro.kernels.tiling` helpers, same
padding), so :mod:`repro.analysis.kernel_audit` can prove bounds /
coverage / write-disjointness / VMEM for a whole shape corpus without
compiling anything. The contracted ``k`` axis (grid axis 3) is the
declared accumulation axis: every output tile is legitimately revisited
once per k-step into the f32 VMEM scratch accumulator.

Corpus cases are given in the *caller's* terms — dense (M, N), group
count G, capacity slack — and compacted through the same
``compute_cap`` rule the plan encoder uses, so the ``slack > 1``
capacity-stretch geometry is part of what gets proven.
"""
from __future__ import annotations

from repro.analysis.kernel_audit import (GridCase, KernelSpec, Operand,
                                         register_kernel_spec)
from repro.kernels.tiling import compute_cap, pick_tile, round_up

F32 = 4


def _tiles(b: int, cap_m: int, cap_n: int):
    bb = pick_tile(b, 128)
    bn = pick_tile(cap_n, 128)
    bk = pick_tile(cap_m, 128)
    return (bb, bn, bk, round_up(b, bb), round_up(cap_m, bk),
            round_up(cap_n, bn))


def _caps(p: dict):
    g = p["g"]
    cap_m = compute_cap(p["m"], g, p.get("slack", 1.0))
    cap_n = compute_cap(p["n"], g, p.get("slack", 1.0))
    return g, cap_m, cap_n


def _label(p: dict) -> str:
    s = p.get("slack", 1.0)
    return (f"b{p['b']}_m{p['m']}_n{p['n']}_g{p['g']}"
            + (f"_slack{s}" if s != 1.0 else ""))


def _tags(p: dict):
    tags = []
    if max(p["m"], p["n"]) > 4096:
        tags.append("m_gt_4096")
    if p.get("slack", 1.0) > 1.0:
        tags.append("slack_gt_1")
    return tuple(tags)


def _grouped_bmm_case(p: dict) -> GridCase:
    g, cap_m, cap_n = _caps(p)
    dt = p.get("itemsize", F32)
    bb, bn, bk, bp, mp, np_ = _tiles(p["b"], cap_m, cap_n)
    grid = (g, bp // bb, np_ // bn, mp // bk)
    return GridCase(
        label=_label(p), grid=grid,
        operands=(
            Operand("xg", (g, bp, mp), (1, bb, bk),
                    lambda gi, i, j, k: (gi, i, k), dt),
            Operand("wc", (g, mp, np_), (1, bk, bn),
                    lambda gi, i, j, k: (gi, k, j), dt),
            Operand("yc", (g, bp, np_), (1, bb, bn),
                    lambda gi, i, j, k: (gi, i, j), dt, role="out"),
        ),
        accum_axes=frozenset({3}),
        scratch_bytes=bb * bn * F32,
        tags=_tags(p),
    )


def _fused_bmm_case(p: dict) -> GridCase:
    g, cap_m, cap_n = _caps(p)
    dt = p.get("itemsize", F32)
    bb, bn, bk, bp, mp, np_ = _tiles(p["b"], cap_m, cap_n)
    m1 = p["m"] + 1                       # appended zero column
    grid = (g, bp // bb, np_ // bn, mp // bk)
    return GridCase(
        label=_label(p), grid=grid,
        operands=(
            # the whole contracted width rides VMEM so the in-kernel
            # activation gather stays local — the VMEM-dominant block
            Operand("xp", (bp, m1), (bb, m1),
                    lambda gi, i, j, k: (i, 0), dt),
            Operand("wc", (g, mp, np_), (1, bk, bn),
                    lambda gi, i, j, k: (gi, k, j), dt),
            Operand("ids", (g, mp), (1, bk),
                    lambda gi, i, j, k: (gi, k), 4),
            Operand("yc", (g, bp, np_), (1, bb, bn),
                    lambda gi, i, j, k: (gi, i, j), dt, role="out"),
        ),
        accum_axes=frozenset({3}),
        scratch_bytes=bb * bn * F32,
        tags=_tags(p),
    )


register_kernel_spec(KernelSpec(
    name="flgw_matmul.grouped_bmm",
    module="repro.kernels.flgw_matmul.flgw_matmul",
    build=_grouped_bmm_case,
    corpus=(
        {"b": 2, "m": 64, "n": 64, "g": 4},           # decode-tiny
        {"b": 128, "m": 1024, "n": 1024, "g": 8},     # training tile
        {"b": 64, "m": 512, "n": 512, "g": 4, "slack": 1.5},
        {"b": 32, "m": 8192, "n": 8192, "g": 16},     # d_ff scale
    ),
    note="XLA-gather grouped path; k accumulates in VMEM scratch",
))

register_kernel_spec(KernelSpec(
    name="flgw_matmul.fused_bmm",
    module="repro.kernels.flgw_matmul.flgw_matmul",
    build=_fused_bmm_case,
    corpus=(
        {"b": 2, "m": 8192, "n": 8192, "g": 4},       # fig13 d_ff decode
        {"b": 128, "m": 256, "n": 256, "g": 4, "slack": 1.5},
        {"b": 8, "m": 4352, "n": 512, "g": 8, "slack": 1.25},
    ),
    note="OSEL-to-core fused path; (bb, M+1) activation block dominates",
))
