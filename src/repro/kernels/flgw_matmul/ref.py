"""Pure-jnp oracles for the FLGW grouped matmul kernel.

Two references:

* ``ref_masked_matmul`` — the paper-faithful algorithm: materialize the FLGW
  mask from the index vectors (OSEL observation 1) and run a dense masked
  matmul. This is the numerical ground truth for both the masked path and the
  grouped/compact path.

* ``ref_grouped_bmm`` — a plain ``einsum`` over the compact (G, capM, capN)
  tiles; oracle for the Pallas batched-matmul kernel proper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.partition import constrain


def ref_masked_matmul(x: jax.Array, w: jax.Array, ig_idx: jax.Array,
                      og_idx: jax.Array) -> jax.Array:
    """y = x @ (W ⊙ Mask), Mask[i,j] = (ig_idx[i] == og_idx[j])."""
    mask = (ig_idx[:, None] == og_idx[None, :]).astype(w.dtype)
    return x @ (w * mask)


def ref_grouped_bmm(xg: jax.Array, wc: jax.Array) -> jax.Array:
    """(G, B, capM) @ (G, capM, capN) -> (G, B, capN) in f32 accumulation."""
    return jnp.einsum(
        "gbm,gmn->gbn", xg, wc,
        preferred_element_type=jnp.float32).astype(xg.dtype)


def ref_grouped_matmul(x: jax.Array, w: jax.Array, row_ids: jax.Array,
                       col_ids: jax.Array, row_valid: jax.Array,
                       col_valid: jax.Array) -> jax.Array:
    """Full compact path in jnp: gather → grouped bmm → scatter.

    row_ids: (G, capM) int32 indices into M (padded entries arbitrary);
    col_ids: (G, capN) int32 indices into N; *_valid are boolean masks of the
    padded slots. Every valid row/col index appears exactly once (balanced
    assignment), so the scatter has no collisions.
    """
    b = x.shape[0]
    n = w.shape[1]
    xg = jnp.take(x, row_ids.reshape(-1), axis=1)  # (B, G*capM)
    xg = xg.reshape(b, *row_ids.shape).transpose(1, 0, 2)  # (G, B, capM)
    xg = jnp.where(row_valid[:, None, :], xg, 0)
    xg = constrain(xg, (None, "batch", None))
    wc = w[row_ids[:, :, None], col_ids[:, None, :]]  # (G, capM, capN)
    wc = jnp.where(row_valid[:, :, None] & col_valid[:, None, :], wc, 0)
    wc = constrain(wc, (None, None, "flgw_cap"))   # intra-layer parallelism
    yc = ref_grouped_bmm(xg, wc)  # (G, B, capN)
    yc = constrain(yc, (None, "batch", "flgw_cap"))
    # Scatter compact outputs back to dense column order; invalid slots are
    # routed to index n and dropped.
    flat_cols = jnp.where(col_valid, col_ids, n).reshape(-1)  # (G*capN,)
    yt = yc.transpose(1, 0, 2).reshape(b, -1)  # (B, G*capN)
    y = jnp.zeros((b, n), x.dtype).at[:, flat_cols].set(yt, mode="drop")
    return y
