"""Jit'd public wrapper around the FLGW grouped-matmul Pallas kernel.

Pipeline (the TPU analogue of LearningGroup's load-allocation unit + cores):

  1. gather   x  -> x_c  (G, B, capM)    activations per group
  2. gather   W  -> W_c  (G, capM, capN) unmasked weights only (÷G bytes)
  3. Pallas   y_c = x_c @ W_c            MXU block-diagonal matmul (÷G FLOPs)
  4. scatter  y_c -> y   (B, N)          compact outputs to dense columns

The gathers/scatter are memory-bound VPU work handled by XLA; the matmul is
the Pallas kernel. On non-TPU backends the kernel runs in interpret mode.

:func:`grouped_matmul_fused` is the OSEL→core variant: step 2's compact
weights come straight from the encode stage (:func:`compact_weights`,
cached beside the plan for the life of a params version) and step 1's
activation gather moves into the kernel prologue — the per-call XLA
gathers disappear from the hot path. :func:`grouped_matmul` (per-call XLA
gathers) remains the no-cached-weights default and, with
``impl="reference"``, the GSPMD-shardable fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flgw_matmul.flgw_matmul import fused_bmm, grouped_bmm
from repro.kernels.flgw_matmul import ref as _ref

# Reference-impl mode: under plain jit, GSPMD cannot partition a pallas
# custom call — it replicates the kernel computation on every chip (the
# gemma2-2b dry-run measured 28x compute). On real TPUs the kernel is
# invoked under shard_map on local blocks; for the CPU dry-run we lower the
# mathematically identical jnp reference instead, which GSPMD shards like
# any einsum. The switch now lives in ``repro.kernels`` (shared with the
# plan_encode kernel); these aliases keep existing callers working.
from repro.kernels import _REF_MODE, use_reference_impl  # noqa: F401
# Tile arithmetic is shared with the static auditor
# (repro.kernels.flgw_matmul.audit) so the audited grid is, by
# construction, the grid this wrapper builds.
from repro.kernels.tiling import pick_tile as _pick_tile
from repro.kernels.tiling import round_up as _round_up


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret", "impl"))
def grouped_matmul(x: jax.Array, w: jax.Array, row_ids: jax.Array,
                   col_ids: jax.Array, row_valid: jax.Array,
                   col_valid: jax.Array, *,
                   interpret: bool | None = None,
                   impl: str = "pallas") -> jax.Array:
    """Compact FLGW matmul. Shapes: x (B, M), w (M, N), row_ids (G, capM),
    col_ids (G, capN); returns y (B, N). See ref.ref_grouped_matmul.

    ``impl="reference"`` lowers the jnp reference instead of the Pallas
    kernel (GSPMD-shardable; see use_reference_impl)."""
    if impl == "reference" or _REF_MODE:
        return _ref.ref_grouped_matmul(x, w, row_ids, col_ids, row_valid,
                                       col_valid)
    if interpret is None:
        interpret = default_interpret()
    b, m = x.shape
    n = w.shape[1]
    g, cap_m = row_ids.shape
    cap_n = col_ids.shape[1]

    # --- gathers -----------------------------------------------------------
    xg = jnp.take(x, row_ids.reshape(-1), axis=1)
    xg = xg.reshape(b, g, cap_m).transpose(1, 0, 2)          # (G, B, capM)
    xg = jnp.where(row_valid[:, None, :], xg, 0)
    wc = w[row_ids[:, :, None], col_ids[:, None, :]]         # (G, capM, capN)
    wc = jnp.where(row_valid[:, :, None] & col_valid[:, None, :], wc, 0)

    # --- pad to tile multiples for the kernel ------------------------------
    bb = _pick_tile(b, 128)
    bn = _pick_tile(cap_n, 128)
    bk = _pick_tile(cap_m, 128)
    bp, mp, np_ = _round_up(b, bb), _round_up(cap_m, bk), _round_up(cap_n, bn)
    xg = jnp.pad(xg, ((0, 0), (0, bp - b), (0, mp - cap_m)))
    wc = jnp.pad(wc, ((0, 0), (0, mp - cap_m), (0, np_ - cap_n)))

    yc = grouped_bmm(xg, wc, bb=bb, bn=bn, bk=bk, interpret=interpret)
    yc = yc[:, :b, :cap_n]                                   # (G, B, capN)

    # --- scatter back to dense column order --------------------------------
    flat_cols = jnp.where(col_valid, col_ids, n).reshape(-1)
    yt = yc.transpose(1, 0, 2).reshape(b, -1)
    return jnp.zeros((b, n), x.dtype).at[:, flat_cols].set(yt, mode="drop")


def compact_weights(w: jax.Array, row_ids: jax.Array, col_ids: jax.Array,
                    row_valid: jax.Array, col_valid: jax.Array) -> jax.Array:
    """``W -> W_c`` (G, capM, capN): the weight half of the encode output.

    This is the paper's OSEL handoff — the dense weight compacted into the
    ``(G, cap)`` format the cores consume directly. Invalid slots are
    zeroed, which is also what makes the fused path bitwise-equal to the
    XLA-gather path: a zero W_c row annihilates whatever the activation
    gather produced for that slot. Handles stacked leading dims (scanned
    decoder layers, vmapped experts) by folding them into a vmap.
    """
    if w.ndim > 2:
        return jax.vmap(compact_weights)(w, row_ids, col_ids, row_valid,
                                         col_valid)
    wc = w[row_ids[:, :, None], col_ids[:, None, :]]         # (G, capM, capN)
    return jnp.where(row_valid[:, :, None] & col_valid[:, None, :], wc, 0)


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def grouped_matmul_fused(x: jax.Array, wc: jax.Array, row_ids: jax.Array,
                         row_valid: jax.Array, col_ids: jax.Array,
                         col_valid: jax.Array, *, n: int,
                         interpret: bool | None = None) -> jax.Array:
    """Compact FLGW matmul consuming the encode output directly.

    Instead of re-gathering both operands through XLA per call
    (:func:`grouped_matmul`), this takes ``wc`` — the ``(G, capM, capN)``
    compact weights from :func:`compact_weights`, typically cached beside
    the plan for the whole life of a params version — and fuses the
    activation gather ``x -> x_c`` into the kernel prologue
    (:func:`~repro.kernels.flgw_matmul.flgw_matmul.fused_bmm`): invalid
    row slots are pointed at a zero column appended to ``x``, so a single
    in-kernel gather replaces XLA's gather + mask + transpose chain.
    Bitwise-identical to :func:`grouped_matmul` (same tile sizes, same
    accumulation order, and zero-masked ``wc`` rows annihilate whatever
    the gather pulls for invalid slots). ``n`` is the dense output width.
    """
    if interpret is None:
        interpret = default_interpret()
    b, m = x.shape
    g, cap_m = row_ids.shape
    cap_n = col_ids.shape[1]
    assert wc.shape == (g, cap_m, cap_n), (wc.shape, row_ids.shape,
                                           col_ids.shape)

    # Invalid/padding slots gather the appended zero column (index m).
    ids = jnp.where(row_valid, row_ids, m)
    xp = jnp.pad(x, ((0, 0), (0, 1)))                        # (B, M+1)

    bb = _pick_tile(b, 128)
    bn = _pick_tile(cap_n, 128)
    bk = _pick_tile(cap_m, 128)
    bp, mp, np_ = _round_up(b, bb), _round_up(cap_m, bk), _round_up(cap_n, bn)
    xp = jnp.pad(xp, ((0, bp - b), (0, 0)))
    ids = jnp.pad(ids, ((0, 0), (0, mp - cap_m)), constant_values=m)
    wc = jnp.pad(wc, ((0, 0), (0, mp - cap_m), (0, np_ - cap_n)))

    yc = fused_bmm(xp, wc, ids, bb=bb, bn=bn, bk=bk, interpret=interpret)
    yc = yc[:, :b, :cap_n]                                   # (G, B, capN)

    flat_cols = jnp.where(col_valid, col_ids, n).reshape(-1)
    yt = yc.transpose(1, 0, 2).reshape(b, -1)
    return jnp.zeros((b, n), x.dtype).at[:, flat_cols].set(yt, mode="drop")


def reference(x, w, row_ids, col_ids, row_valid, col_valid):
    return _ref.ref_grouped_matmul(x, w, row_ids, col_ids, row_valid,
                                   col_valid)
