"""Jit'd public wrapper around the plan-encode (balanced-assign) kernel.

Pipeline (the TPU analogue of the FPGA's load-allocation unit):

  1. argmax    scores -> (pref, strength)   per-item group preference (VPU)
  2. Pallas    comparator-rank counting sort + prefix-sum placement
               (two tiled passes — see ``plan_encode.assign_slots``)
  3. scatter   slot_of_item -> (G, cap) buckets (inverse permutation, XLA)

Leading batch dims are folded into the kernel grid (stacked decoder layers
encode in one launch — no vmap-of-pallas needed). On non-TPU backends the
kernel runs in interpret mode; ``impl="reference"`` (or the shared
``repro.kernels.use_reference_impl`` switch, for GSPMD lowering) falls back
to the lexsort reference in ``ref.py``. There is no size cap: the placement
passes tile over ``(bi, bj)`` item pairs, so the VMEM working set is
independent of the item count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import reference_impl_active
from repro.kernels.plan_encode import ref as _ref
from repro.kernels.plan_encode.plan_encode import assign_slots
# Placement-tile selection is shared with the static auditor
# (repro.kernels.plan_encode.audit) so the audited grid is, by
# construction, the grid this wrapper builds. Override per call
# (``balanced_assign(block=...)``) to force the multi-tile path on small
# inputs in tests.
from repro.kernels.tiling import plan_block as _plan_block
from repro.kernels.tiling import round_up as _round_up


def resolve_impl(items: int, impl: str | None = None) -> str:
    """Which implementation an ``items``-row encode will run — the single
    impl-selection policy, exposed so tests can assert on it.

    An **explicit** ``impl`` is binding. **Implicit** resolution
    (``impl=None``) prefers the kernel and falls back to the
    bitwise-identical lexsort reference only under the shared
    ``repro.kernels.use_reference_impl`` switch (intentional, silent —
    GSPMD cannot partition a Pallas custom call). Since the placement
    pass was tiled there is no size-based fallback: any ``items`` count
    runs the kernel, so ``items`` no longer affects the answer and is
    kept for call-site compatibility only.
    """
    if impl is not None:
        if impl not in ("pallas", "reference"):
            raise ValueError(
                f"impl must be 'pallas' or 'reference', got {impl!r}")
        return impl
    if reference_impl_active():
        return "reference"
    return "pallas"


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("axis", "slack", "interpret",
                                             "impl", "block"))
def _balanced_assign(scores: jax.Array, axis: int, slack: float,
                     interpret: bool | None, impl: str,
                     block: int | None) -> jax.Array:
    # The assignment is pure int metadata — no gradient ever flows through
    # it (the STE surrogate lives in grouped_apply's VJP). Cutting the
    # tangent here keeps jvp/grad of plan-deriving callers from trying to
    # differentiate the Pallas call.
    scores = jax.lax.stop_gradient(scores)
    if axis == 0:
        scores = jnp.swapaxes(scores, -1, -2)
    lead = scores.shape[:-2]
    m, g = scores.shape[-2:]
    cap = _ref.compute_cap(m, g, slack)
    if impl == "reference":
        f = functools.partial(_ref.ref_balanced_assign, slack=slack)
        for _ in lead:
            f = jax.vmap(f)
        return f(scores)
    if interpret is None:
        interpret = default_interpret()

    flat = scores.reshape((-1, m, g)) if lead else scores[None]
    length = flat.shape[0]
    pref = jnp.argmax(flat, axis=-1).astype(jnp.int32)       # (L, M)
    strength = jnp.max(flat, axis=-1).astype(jnp.float32)
    b = _plan_block(m, block)
    mp = _round_up(m, b)
    # Padding items: sentinel group g, -inf strength — never counted, never
    # placed (their garbage slots are sliced off below).
    pref = jnp.pad(pref, ((0, 0), (0, mp - m)), constant_values=g)
    strength = jnp.pad(strength, ((0, 0), (0, mp - m)),
                       constant_values=-jnp.inf)
    slot = assign_slots(pref[..., None], strength[..., None],
                        pref[:, None, :], strength[:, None, :],
                        g=g, cap=cap, bi=b, bj=b, interpret=interpret)
    slot = slot[:, :m, 0]                                    # (L, M)

    # Inverse permutation: bucket slot ids back to (G, cap) item lists.
    total = g * cap
    ids = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None],
                           (length, m))
    out = (jnp.full((length, total), m, jnp.int32)
           .at[jnp.arange(length)[:, None], slot].set(ids, mode="drop"))
    if lead:
        return out.reshape(*lead, g, cap)
    return out[0].reshape(g, cap)


def balanced_assign(scores: jax.Array, axis: int, slack: float = 1.0, *,
                    interpret: bool | None = None,
                    impl: str | None = None,
                    block: int | None = None) -> jax.Array:
    """Deal items into equal-capacity groups by argmax preference.

    ``scores``: (..., M, G) if axis==1 (rows of IG) or (..., G, N) if
    axis==0 (columns of OG); leading dims batch over stacked layers.
    Returns (..., G, cap) int32 item ids with ``cap = ceil(M/G · slack)``
    (padding slots hold M). Bitwise-identical to
    :func:`ref.ref_balanced_assign` for finite scores at any M — the
    placement passes tile, so there is no kernel size cap.

    ``block`` overrides the placement tile side (must stay a multiple of
    the 128-lane quantum for real-TPU layouts; tests force small tiles to
    drive the multi-tile path under interpret mode). Implementation
    selection (Pallas kernel vs lexsort reference) follows
    :func:`resolve_impl`.
    """
    items = scores.shape[-2] if axis else scores.shape[-1]
    impl = resolve_impl(items, impl)
    return _balanced_assign(scores, axis, slack, interpret, impl, block)


def reference(scores: jax.Array, axis: int, slack: float = 1.0) -> jax.Array:
    """The lexsort oracle (unbatched input)."""
    if axis == 0:
        scores = scores.T
    return _ref.ref_balanced_assign(scores, slack)
