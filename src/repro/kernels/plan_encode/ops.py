"""Jit'd public wrapper around the plan-encode (balanced-assign) kernel.

Pipeline (the TPU analogue of the FPGA's load-allocation unit):

  1. argmax    scores -> (pref, strength)   per-item group preference (VPU)
  2. Pallas    comparator-rank counting sort + prefix-sum placement
  3. scatter   slot_of_item -> (G, cap) buckets (inverse permutation, XLA)

Leading batch dims are folded into the kernel grid (stacked decoder layers
encode in one launch — no vmap-of-pallas needed). On non-TPU backends the
kernel runs in interpret mode; ``impl="reference"`` (or the shared
``repro.kernels.use_reference_impl`` switch, for GSPMD lowering) and
oversized inputs fall back to the lexsort reference in ``ref.py``.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.kernels import reference_impl_active
from repro.kernels.plan_encode import ref as _ref
from repro.kernels.plan_encode.plan_encode import assign_slots

# Above this item count the (Mp, bj) comparator tiles outgrow VMEM; the
# encode is off the hot path, so just use the XLA reference there.
_MAX_ITEMS = 4096

# The implicit size fallback warns once per process. Mutate it only
# through the helpers below — direct writes from tests used to leak
# between test files (the last writer decided whether any later oversize
# encode in the same process could warn at all).
_size_fallback_warned = False


def size_fallback_warned() -> bool:
    """Whether the once-per-process oversize-fallback warning has fired."""
    return _size_fallback_warned


def reset_size_fallback_warning(warned: bool = False) -> bool:
    """Set the once-per-process warning latch; returns the previous value.

    ``reset_size_fallback_warning()`` re-arms the warning (a test that
    asserts on it fires regardless of what ran earlier in the process);
    ``reset_size_fallback_warning(True)`` silences it for noise-sensitive
    blocks. Pair with the returned previous value — or rely on the
    autouse fixture in ``tests/conftest.py``, which snapshots and
    restores the latch around every test.
    """
    global _size_fallback_warned
    prev = _size_fallback_warned
    _size_fallback_warned = bool(warned)
    return prev


def resolve_impl(items: int, impl: str | None = None) -> str:
    """Which implementation an ``items``-row encode will run — the single
    impl-selection policy, exposed so tests can assert on it.

    An **explicit** ``impl`` is binding: requesting ``"pallas"`` above the
    ``_MAX_ITEMS`` tile cap raises instead of silently degrading (the old
    behavior ignored the request — a caller pinning the kernel for a perf
    run would measure the lexsort reference without knowing). **Implicit**
    resolution (``impl=None``) prefers the kernel and falls back to the
    bitwise-identical lexsort reference under the shared
    ``repro.kernels.use_reference_impl`` switch (intentional, silent) or
    above the size cap (one ``RuntimeWarning`` per process).
    """
    global _size_fallback_warned
    if impl is not None:
        if impl not in ("pallas", "reference"):
            raise ValueError(
                f"impl must be 'pallas' or 'reference', got {impl!r}")
        if impl == "pallas" and items > _MAX_ITEMS:
            raise ValueError(
                f"plan_encode: impl='pallas' was requested explicitly, but "
                f"{items} items exceed the kernel's tile cap "
                f"_MAX_ITEMS={_MAX_ITEMS} — the (Mp, bj) comparator tile "
                "would outgrow VMEM. Pass impl='reference' (bitwise-"
                "identical lexsort) or drop impl= for the automatic "
                "fallback; tiling the placement pass to lift the cap is a "
                "ROADMAP item.")
        return impl
    if reference_impl_active():
        return "reference"
    if items > _MAX_ITEMS:
        if not _size_fallback_warned:
            _size_fallback_warned = True
            warnings.warn(
                f"plan_encode: {items} items exceed the Pallas tile cap "
                f"({_MAX_ITEMS}); falling back to the lexsort reference "
                "(bitwise-identical, slower). Pass impl='reference' to "
                "acknowledge, or impl='pallas' to make this an error. "
                "(warned once per process)",
                RuntimeWarning, stacklevel=3)
        return "reference"
    return "pallas"


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit,
                   static_argnames=("axis", "slack", "interpret", "impl"))
def _balanced_assign(scores: jax.Array, axis: int, slack: float,
                     interpret: bool | None, impl: str) -> jax.Array:
    # The assignment is pure int metadata — no gradient ever flows through
    # it (the STE surrogate lives in grouped_apply's VJP). Cutting the
    # tangent here keeps jvp/grad of plan-deriving callers from trying to
    # differentiate the Pallas call.
    scores = jax.lax.stop_gradient(scores)
    if axis == 0:
        scores = jnp.swapaxes(scores, -1, -2)
    lead = scores.shape[:-2]
    m, g = scores.shape[-2:]
    cap = _ref.compute_cap(m, g, slack)
    if impl == "reference":
        f = functools.partial(_ref.ref_balanced_assign, slack=slack)
        for _ in lead:
            f = jax.vmap(f)
        return f(scores)
    if interpret is None:
        interpret = default_interpret()

    flat = scores.reshape((-1, m, g)) if lead else scores[None]
    length = flat.shape[0]
    pref = jnp.argmax(flat, axis=-1).astype(jnp.int32)       # (L, M)
    strength = jnp.max(flat, axis=-1).astype(jnp.float32)
    bj = min(256, _round_up(m, 128))
    mp = _round_up(m, bj)
    # Padding items: sentinel group g, -inf strength — never counted, never
    # placed (their garbage slots are sliced off below).
    pref = jnp.pad(pref, ((0, 0), (0, mp - m)), constant_values=g)
    strength = jnp.pad(strength, ((0, 0), (0, mp - m)),
                       constant_values=-jnp.inf)
    slot = assign_slots(pref[..., None], strength[..., None],
                        pref[:, None, :], strength[:, None, :],
                        g=g, cap=cap, bj=bj, interpret=interpret)
    slot = slot[:, :m, 0]                                    # (L, M)

    # Inverse permutation: bucket slot ids back to (G, cap) item lists.
    total = g * cap
    ids = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None],
                           (length, m))
    out = (jnp.full((length, total), m, jnp.int32)
           .at[jnp.arange(length)[:, None], slot].set(ids, mode="drop"))
    if lead:
        return out.reshape(*lead, g, cap)
    return out[0].reshape(g, cap)


def balanced_assign(scores: jax.Array, axis: int, slack: float = 1.0, *,
                    interpret: bool | None = None,
                    impl: str | None = None) -> jax.Array:
    """Deal items into equal-capacity groups by argmax preference.

    ``scores``: (..., M, G) if axis==1 (rows of IG) or (..., G, N) if
    axis==0 (columns of OG); leading dims batch over stacked layers.
    Returns (..., G, cap) int32 item ids with ``cap = ceil(M/G · slack)``
    (padding slots hold M). Bitwise-identical to
    :func:`ref.ref_balanced_assign` for finite scores.

    Implementation selection (Pallas kernel vs lexsort reference) follows
    :func:`resolve_impl`: explicit ``impl`` binds (oversized ``"pallas"``
    raises), implicit oversize falls back with a one-time warning.
    """
    items = scores.shape[-2] if axis else scores.shape[-1]
    impl = resolve_impl(items, impl)
    return _balanced_assign(scores, axis, slack, interpret, impl)


def reference(scores: jax.Array, axis: int, slack: float = 1.0) -> jax.Array:
    """The lexsort oracle (unbatched input)."""
    if axis == 0:
        scores = scores.T
    return _ref.ref_balanced_assign(scores, slack)
