"""KernelSpecs for the plan-encode (balanced-assign) kernels (jax-free).

Two ``pallas_call`` sites in :mod:`plan_encode.assign_slots`:

* **rank** — grid ``(L, Mp/b, Mp/b)``: the j-tile axis (grid axis 2) is
  the declared accumulation axis; rank and the per-i-tile histogram are
  flushed at the last j tile, so both outputs are revisited ``n_jt``
  times consecutively.
* **place** — grid ``(L, Mp/b)``: every slot tile written exactly once,
  while the full per-layer ``(n_it, G)`` histogram rides along as an
  in-block broadcast — the one operand here whose VMEM cost grows with
  M (by ``M / b`` rows), which is exactly what the vmem check watches.

Tiling mirrors ``ops.py`` via :func:`repro.kernels.tiling.plan_block`:
the lifted 4096-item cap means the corpus must prove the multi-tile
geometry, so cases force ``block`` below M and push M well past 4096.
"""
from __future__ import annotations

from repro.analysis.kernel_audit import (GridCase, KernelSpec, Operand,
                                         register_kernel_spec)
from repro.kernels.tiling import plan_block, round_up

I32 = 4
F32 = 4


def _geom(p: dict):
    m = p["m"]
    b = plan_block(m, p.get("block"))
    mp = round_up(m, b)
    return p["l"], m, p["g"], b, mp, mp // b


def _label(p: dict) -> str:
    blk = p.get("block")
    return (f"l{p['l']}_m{p['m']}_g{p['g']}"
            + (f"_b{blk}" if blk else ""))


def _tags(p: dict):
    return ("m_gt_4096",) if p["m"] > 4096 else ()


def _rank_case(p: dict) -> GridCase:
    l, m, g, b, mp, n_t = _geom(p)
    return GridCase(
        label=_label(p), grid=(l, n_t, n_t),
        operands=(
            Operand("pref_c", (l, mp, 1), (1, b, 1),
                    lambda i, ti, tj: (i, ti, 0), I32),
            Operand("str_c", (l, mp, 1), (1, b, 1),
                    lambda i, ti, tj: (i, ti, 0), F32),
            Operand("pref_r", (l, 1, mp), (1, 1, b),
                    lambda i, ti, tj: (i, 0, tj), I32),
            Operand("str_r", (l, 1, mp), (1, 1, b),
                    lambda i, ti, tj: (i, 0, tj), F32),
            Operand("rank", (l, mp, 1), (1, b, 1),
                    lambda i, ti, tj: (i, ti, 0), I32, role="out"),
            Operand("hist", (l, n_t, g), (1, 1, g),
                    lambda i, ti, tj: (i, ti, 0), I32, role="out"),
        ),
        accum_axes=frozenset({2}),
        scratch_bytes=b * 1 * I32,
        tags=_tags(p),
    )


def _place_case(p: dict) -> GridCase:
    l, m, g, b, mp, n_t = _geom(p)
    return GridCase(
        label=_label(p), grid=(l, n_t),
        operands=(
            Operand("pref_c", (l, mp, 1), (1, b, 1),
                    lambda i, ti: (i, ti, 0), I32),
            Operand("rank", (l, mp, 1), (1, b, 1),
                    lambda i, ti: (i, ti, 0), I32),
            Operand("hist", (l, n_t, g), (1, n_t, g),
                    lambda i, ti: (i, 0, 0), I32),
            Operand("slot", (l, mp, 1), (1, b, 1),
                    lambda i, ti: (i, ti, 0), I32, role="out"),
        ),
        tags=_tags(p),
    )


_CORPUS = (
    {"l": 1, "m": 256, "g": 4, "block": 128},   # forced multi-tile
    {"l": 2, "m": 4352, "g": 8},                # past the lifted cap
    {"l": 1, "m": 8192, "g": 64},               # d_ff-scale histogram
)

register_kernel_spec(KernelSpec(
    name="plan_encode.rank",
    module="repro.kernels.plan_encode.plan_encode",
    build=_rank_case, corpus=_CORPUS,
    note="comparator-rank pass; j-tile axis accumulates",
))
register_kernel_spec(KernelSpec(
    name="plan_encode.place",
    module="repro.kernels.plan_encode.plan_encode",
    build=_place_case, corpus=_CORPUS,
    note="prefix-sum placement; every tile written once",
))
