"""Pallas TPU kernel: capacity-balanced group assignment (plan encode).

The OSEL analogue's last host-shaped remnant was ``balanced_assign``'s
global ``jnp.lexsort`` — a serial sort idiom XLA lowers outside any kernel.
This kernel replaces the sort with the comparator-array formulation the
FPGA's load-allocation unit suggests: counting sort by pairwise compares
plus prefix sums, all on VMEM tiles.

For every item ``i`` (a row of IG or a column of OG) the inputs are its
argmax group ``pref[i]`` and preference strength ``strength[i]``. The
placement is **fully tiled** — two passes over ``(bi, bj)`` item-tile
pairs, so the VMEM working set is ``(bi, bj)`` regardless of M and the
old 4096-item cap is gone:

  1. **rank** — grid ``(L, Mp/bi, Mp/bj)``: ``rank[i]`` counts the items
     of the same group that sort strictly before ``i`` (stronger, or equal
     strength with a smaller global index — the lexsort's stable
     tie-break), accumulated tile pair by tile pair in a ``(bi, 1)``
     scratch. At the last ``j`` tile the kernel also emits the i-tile's
     per-group histogram (one ``(1, G)`` row per tile) — the cross-tile
     carry the placement pass needs.
  2. **place** — grid ``(L, Mp/bi)``: per-group totals from the summed
     tile histograms, exclusive prefix sums over the G groups (a (G, G)
     strict-upper mask — the prefix-sum half of the formulation), and the
     closed-form slot of every item: kept items go to ``pref·cap + rank``;
     overflow items (``rank >= cap``) take the free slots in ascending
     slot order, located by matching their global overflow rank against
     the per-group free-slot ranges. Because rank and the histograms are
     global quantities, every i-tile places independently — spills that
     cross tile boundaries land bitwise where the lexsort puts them.

Output is ``slot_of_item`` (L, Mp, 1) int32; the inverse permutation
scatter back to ``(G, cap)`` buckets is memory-bound VPU work left to XLA
(the same split as ``flgw_matmul``'s gathers). Bitwise-identical to the
lexsort reference for finite scores; signed-zero strength ties may legally
differ (the reference sorts on ``-strength`` where ``-0.0 == 0.0``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _rank_kernel(pref_c_ref, str_c_ref, pref_r_ref, str_r_ref, rank_ref,
                 hist_ref, acc_ref, *, g: int, bi: int, bj: int, n_jt: int):
    """One (l, i-tile, j-tile) grid step of the comparator-rank pass."""
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pref_c = pref_c_ref[0]                                # (bi, 1) int32
    str_c = str_c_ref[0]                                  # (bi, 1) f32
    pref_j = pref_r_ref[0]                                # (1, bj)
    str_j = str_r_ref[0]                                  # (1, bj)
    ii = i * bi + jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 0)
    jj = j * bj + jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 1)
    same = pref_c == pref_j                               # (bi, bj)
    before = (str_j > str_c) | ((str_j == str_c) & (jj < ii))
    acc_ref[...] += jnp.sum((same & before).astype(jnp.int32),
                            axis=1, keepdims=True)

    @pl.when(j == n_jt - 1)
    def _emit():
        rank_ref[0] = acc_ref[...]
        # This i-tile's group histogram — padding items carry the sentinel
        # group ``g`` and drop out of the (bi, G) one-hot.
        gi_row = jax.lax.broadcasted_iota(jnp.int32, (bi, g), 1)
        onehot = (pref_c == gi_row).astype(jnp.int32)
        hist_ref[0] = jnp.sum(onehot, axis=0, keepdims=True)   # (1, G)


def _place_kernel(pref_c_ref, rank_ref, hist_ref, slot_ref, *, g: int,
                  cap: int, bi: int):
    """One (l, i-tile) grid step of the cross-tile placement pass."""
    # Per-group totals: sum of every i-tile's histogram (the cross-tile
    # reduction). Row layout for per-item gathers via the one-hot; the
    # column layout for the (G, G) prefix sums comes from an eye-mask
    # select (no (1, G) -> (G, 1) transposes in-kernel).
    counts_row = jnp.sum(hist_ref[0], axis=0, keepdims=True)       # (1, G)
    eye = (jax.lax.broadcasted_iota(jnp.int32, (g, g), 0)
           == jax.lax.broadcasted_iota(jnp.int32, (g, g), 1))
    counts_col = jnp.sum(
        jnp.where(eye, jnp.broadcast_to(counts_row, (g, g)), 0),
        axis=1, keepdims=True)                                     # (G, 1)
    kcount_row = jnp.minimum(counts_row, cap)
    kcount_col = jnp.minimum(counts_col, cap)
    # Exclusive prefix sums over groups: strict-upper (G, G) mask.
    tri = (jax.lax.broadcasted_iota(jnp.int32, (g, g), 0)
           < jax.lax.broadcasted_iota(jnp.int32, (g, g), 1))
    ovf_before = jnp.sum(jnp.where(tri, counts_col - kcount_col, 0),
                         axis=0, keepdims=True)                    # (1, G)
    free_before = jnp.sum(jnp.where(tri, cap - kcount_col, 0),
                          axis=0, keepdims=True)                   # (1, G)

    pref_c = pref_c_ref[0]                                # (bi, 1) int32
    rank = rank_ref[0]                                    # (bi, 1) int32
    gi_row = jax.lax.broadcasted_iota(jnp.int32, (bi, g), 1)
    onehot = (pref_c == gi_row).astype(jnp.int32)         # (bi, G)

    def sel(row_vec):                                     # gather by pref
        return jnp.sum(onehot * row_vec, axis=1, keepdims=True)

    keep = rank < cap
    kept_slot = pref_c * cap + jnp.minimum(rank, cap - 1)
    # Overflow: global overflow rank, then match against the ascending
    # free-slot ranges [free_before[g], free_before[g] + nfree[g]).
    q = sel(ovf_before) + rank - cap                      # (bi, 1)
    nfree_row = cap - kcount_row                          # (1, G)
    match = ((q >= free_before) & (q < free_before + nfree_row)
             ).astype(jnp.int32)                          # (bi, G)
    gsel = jnp.sum(match * gi_row, axis=1, keepdims=True)
    kc_sel = jnp.sum(match * kcount_row, axis=1, keepdims=True)
    lo_sel = jnp.sum(match * free_before, axis=1, keepdims=True)
    ovf_slot = gsel * cap + kc_sel + (q - lo_sel)
    slot_ref[0] = jnp.where(keep, kept_slot, ovf_slot).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("g", "cap", "bi", "bj", "interpret"))
def assign_slots(pref_c: jax.Array, str_c: jax.Array, pref_r: jax.Array,
                 str_r: jax.Array, *, g: int, cap: int, bi: int, bj: int,
                 interpret: bool = False) -> jax.Array:
    """(L, Mp, 1)+(L, 1, Mp) pref/strength -> (L, Mp, 1) int32 slot ids.

    ``Mp`` must be a multiple of both ``bi`` and ``bj`` (ops.py pads;
    padding items carry ``pref == g`` / ``strength == -inf`` and produce
    garbage slots the caller drops). VMEM per rank step: the (bi, bj)
    comparator tile plus the (bi, G) one-hot — independent of M, so any
    item count tiles through; the cross-tile state is one (n_it, G)
    histogram per layer.
    """
    l, mp, _ = pref_c.shape
    assert mp % bi == 0 and mp % bj == 0, (mp, bi, bj)
    n_it = mp // bi
    n_jt = mp // bj

    rank, hist = pl.pallas_call(
        functools.partial(_rank_kernel, g=g, bi=bi, bj=bj, n_jt=n_jt),
        grid=(l, n_it, n_jt),
        in_specs=[
            pl.BlockSpec((1, bi, 1), lambda i, ti, tj: (i, ti, 0)),
            pl.BlockSpec((1, bi, 1), lambda i, ti, tj: (i, ti, 0)),
            pl.BlockSpec((1, 1, bj), lambda i, ti, tj: (i, 0, tj)),
            pl.BlockSpec((1, 1, bj), lambda i, ti, tj: (i, 0, tj)),
        ],
        out_specs=[
            pl.BlockSpec((1, bi, 1), lambda i, ti, tj: (i, ti, 0)),
            pl.BlockSpec((1, 1, g), lambda i, ti, tj: (i, ti, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l, mp, 1), jnp.int32),
            jax.ShapeDtypeStruct((l, n_it, g), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((bi, 1), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(pref_c, str_c, pref_r, str_r)

    return pl.pallas_call(
        functools.partial(_place_kernel, g=g, cap=cap, bi=bi),
        grid=(l, n_it),
        in_specs=[
            pl.BlockSpec((1, bi, 1), lambda i, ti: (i, ti, 0)),
            pl.BlockSpec((1, bi, 1), lambda i, ti: (i, ti, 0)),
            pl.BlockSpec((1, n_it, g), lambda i, ti: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bi, 1), lambda i, ti: (i, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((l, mp, 1), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pref_c, rank, hist)
