"""Pallas TPU kernel: capacity-balanced group assignment (plan encode).

The OSEL analogue's last host-shaped remnant was ``balanced_assign``'s
global ``jnp.lexsort`` — a serial sort idiom XLA lowers outside any kernel.
This kernel replaces the sort with the comparator-array formulation the
FPGA's load-allocation unit suggests: counting sort by pairwise compares
plus prefix sums, all on VMEM tiles.

For every item ``i`` (a row of IG or a column of OG) the inputs are its
argmax group ``pref[i]`` and preference strength ``strength[i]``. One grid
walks ``(L, Mp/bj)``:

  1. **rank** — accumulated over ``j`` tiles: ``rank[i]`` counts the items
     of the same group that sort strictly before ``i`` (stronger, or equal
     strength with a smaller index — the lexsort's stable tie-break). This
     is the counting-sort key: no data movement, only an (Mp, bj)
     comparator tile per step.
  2. **place** — at the last tile: per-group histograms, exclusive prefix
     sums over the G groups (a (G, G) strict-upper mask — the prefix-sum
     half of the formulation), and the closed-form slot of every item:
     kept items go to ``pref·cap + rank``; overflow items (``rank >= cap``)
     take the free slots in ascending slot order, located by matching their
     global overflow rank against the per-group free-slot ranges.

Output is ``slot_of_item`` (L, Mp, 1) int32; the inverse permutation
scatter back to ``(G, cap)`` buckets is memory-bound VPU work left to XLA
(the same split as ``flgw_matmul``'s gathers). Bitwise-identical to the
lexsort reference for finite scores; signed-zero strength ties may legally
differ (the reference sorts on ``-strength`` where ``-0.0 == 0.0``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params


def _assign_kernel(pref_c_ref, str_c_ref, pref_r_ref, str_r_ref, slot_ref,
                   rank_ref, *, g: int, cap: int, bj: int, n_jt: int):
    """One (l, j-tile) grid step; see module docstring."""
    j = pl.program_id(1)
    mp = rank_ref.shape[0]

    @pl.when(j == 0)
    def _zero():
        rank_ref[...] = jnp.zeros_like(rank_ref)

    pref_c = pref_c_ref[0]                                # (Mp, 1) int32
    str_c = str_c_ref[0]                                  # (Mp, 1) f32
    pref_j = pref_r_ref[0, :, pl.dslice(j * bj, bj)]      # (1, bj)
    str_j = str_r_ref[0, :, pl.dslice(j * bj, bj)]        # (1, bj)
    ii = jax.lax.broadcasted_iota(jnp.int32, (mp, bj), 0)
    jj = j * bj + jax.lax.broadcasted_iota(jnp.int32, (mp, bj), 1)
    same = pref_c == pref_j                               # (Mp, bj)
    before = (str_j > str_c) | ((str_j == str_c) & (jj < ii))
    rank_ref[...] += jnp.sum((same & before).astype(jnp.int32),
                             axis=1, keepdims=True)

    @pl.when(j == n_jt - 1)
    def _place():
        rank = rank_ref[...]                              # (Mp, 1)
        # Group histograms in both layouts (row for per-item gathers via
        # the one-hot, column for the (G, G) prefix sums) — padding items
        # carry the sentinel group ``g`` and drop out of both.
        gi_row = jax.lax.broadcasted_iota(jnp.int32, (mp, g), 1)
        onehot = (pref_c == gi_row).astype(jnp.int32)     # (Mp, G)
        counts_row = jnp.sum(onehot, axis=0, keepdims=True)        # (1, G)
        gi_col = jax.lax.broadcasted_iota(jnp.int32, (g, mp), 0)
        onehot_t = (gi_col == pref_r_ref[0]).astype(jnp.int32)     # (G, Mp)
        counts_col = jnp.sum(onehot_t, axis=1, keepdims=True)      # (G, 1)
        kcount_row = jnp.minimum(counts_row, cap)
        kcount_col = jnp.minimum(counts_col, cap)
        # Exclusive prefix sums over groups: strict-upper (G, G) mask.
        tri = (jax.lax.broadcasted_iota(jnp.int32, (g, g), 0)
               < jax.lax.broadcasted_iota(jnp.int32, (g, g), 1))
        ovf_before = jnp.sum(jnp.where(tri, counts_col - kcount_col, 0),
                             axis=0, keepdims=True)                # (1, G)
        free_before = jnp.sum(jnp.where(tri, cap - kcount_col, 0),
                              axis=0, keepdims=True)               # (1, G)

        def sel(row_vec):                                 # gather by pref
            return jnp.sum(onehot * row_vec, axis=1, keepdims=True)

        keep = rank < cap
        kept_slot = pref_c * cap + jnp.minimum(rank, cap - 1)
        # Overflow: global overflow rank, then match against the ascending
        # free-slot ranges [free_before[g], free_before[g] + nfree[g]).
        q = sel(ovf_before) + rank - cap                  # (Mp, 1)
        nfree_row = cap - kcount_row                      # (1, G)
        match = ((q >= free_before) & (q < free_before + nfree_row)
                 ).astype(jnp.int32)                      # (Mp, G)
        gsel = jnp.sum(match * gi_row, axis=1, keepdims=True)
        kc_sel = jnp.sum(match * kcount_row, axis=1, keepdims=True)
        lo_sel = jnp.sum(match * free_before, axis=1, keepdims=True)
        ovf_slot = gsel * cap + kc_sel + (q - lo_sel)
        slot_ref[0] = jnp.where(keep, kept_slot, ovf_slot).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("g", "cap", "bj", "interpret"))
def assign_slots(pref_c: jax.Array, str_c: jax.Array, pref_r: jax.Array,
                 str_r: jax.Array, *, g: int, cap: int, bj: int,
                 interpret: bool = False) -> jax.Array:
    """(L, Mp, 1)+(L, 1, Mp) pref/strength -> (L, Mp, 1) int32 slot ids.

    ``Mp`` must be a multiple of ``bj`` (ops.py pads; padding items carry
    ``pref == g`` / ``strength == -inf`` and produce garbage slots the
    caller drops). VMEM per step: the (Mp, bj) comparator tile plus the
    (Mp, G) one-hots — ~6 MB at Mp=4096, bj=256, G=128.
    """
    l, mp, _ = pref_c.shape
    assert mp % bj == 0, (mp, bj)
    n_jt = mp // bj
    return pl.pallas_call(
        functools.partial(_assign_kernel, g=g, cap=cap, bj=bj, n_jt=n_jt),
        grid=(l, n_jt),
        in_specs=[
            pl.BlockSpec((1, mp, 1), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, mp, 1), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, 1, mp), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, 1, mp), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, mp, 1), lambda i, j: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((l, mp, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((mp, 1), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(pref_c, str_c, pref_r, str_r)
