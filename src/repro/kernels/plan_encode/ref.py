"""Oracle for the plan-encode kernel: the lexsort capacity-balanced deal.

This is the original host-shaped idiom the kernel replaces — a global
``jnp.lexsort`` over (group preference, confidence) followed by
``searchsorted`` bucketing. It remains the semantic ground truth: the
Pallas kernel must place every item in the *bitwise identical* slot,
including the spill order of overflow items under ``slack > 1``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compute_cap(m: int, g: int, slack: float = 1.0) -> int:
    """Static per-group capacity: ``ceil(m/g)``, stretched by ``slack``."""
    cap = max(1, -(-m // g))
    return min(m, int(-(-cap * slack // 1))) if slack > 1.0 else cap


def ref_balanced_assign(scores: jax.Array, slack: float = 1.0) -> jax.Array:
    """Lexsort reference. ``scores``: (M, G) preference matrix; returns
    (G, cap) int32 item ids (padding slots hold ``M``).

    Items are sorted by (argmax group asc, strength desc, index asc); each
    group keeps its ``cap`` most confident items, overflow items take the
    remaining free slots in ascending slot order.
    """
    m, g = scores.shape
    cap = compute_cap(m, g, slack)
    total = g * cap
    pref = jnp.argmax(scores, axis=1)          # (M,)
    strength = jnp.max(scores, axis=1)
    # Sort by (pref asc, strength desc): within a group, confident items
    # first, so spill-over moves the *least* confident items.
    order = jnp.lexsort((-strength, pref))     # (M,)
    pref_sorted = pref[order]
    first = jnp.searchsorted(pref_sorted, jnp.arange(g))     # group starts
    rank = jnp.arange(m) - first[pref_sorted]                # rank in group
    keep = rank < cap
    kept_slot = pref_sorted * cap + jnp.minimum(rank, cap - 1)
    # Free slots: slot (gi, r) is free iff r >= (kept count of gi).
    counts = jnp.minimum(jnp.bincount(pref, length=g), cap)
    sidx = jnp.arange(total)
    free = (sidx % cap) >= counts[sidx // cap]
    free_slots = jnp.argsort(~free, stable=True)   # free slot ids, ascending
    ovf_rank = jnp.cumsum(~keep) - 1
    slot = jnp.where(keep, kept_slot,
                     free_slots[jnp.clip(ovf_rank, 0, total - 1)])
    row_of_slot = (jnp.full((total,), m, jnp.int32)
                   .at[slot].set(order.astype(jnp.int32), mode="drop"))
    return row_of_slot.reshape(g, cap)
