"""Shared tile-size arithmetic for the Pallas kernels — jax-free.

Every kernel wrapper derives its grid and BlockSpec shapes from the same
handful of integer helpers. They live here, outside any jax import, so
the static kernel auditor (:mod:`repro.analysis.kernel_audit`) and the
per-package ``audit.py`` KernelSpec modules can re-derive the *exact*
grids the wrappers build without pulling in jax — the CI analysis job
runs without jax installed. Keeping one copy also removes the
drift hazard of the auditor modelling different tiling math than the
kernels execute: both sides call these functions.
"""
from __future__ import annotations

# Default plan_encode placement tile (items per comparator-tile side).
# 512 keeps the (bi, bj) int32/f32 rank-pass tiles ~1 MiB each — far
# under VMEM at any M.
DEFAULT_PLAN_BLOCK = 512


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return (x + m - 1) // m * m


def pick_tile(dim: int, pref: int) -> int:
    """flgw_matmul tile rule: largest tile <= pref that keeps padding
    small; multiples of 8 (sublane quantum)."""
    if dim >= pref:
        return pref
    return max(8, round_up(dim, 8))


def pick_block(n: int, pref: int) -> int:
    """flash_attention block rule: the largest divisor of ``n`` that is
    <= ``pref``, preferring multiples of 128 (MXU/lane alignment)."""
    if n <= pref:
        return n
    for c in range(pref, 127, -128):
        if n % c == 0:
            return c
    for c in range(pref, 0, -1):
        if n % c == 0:
            return c
    return n


def plan_block(m: int, block: int | None = None) -> int:
    """plan_encode placement tile rule (``ops._balanced_assign``)."""
    return block if block else min(DEFAULT_PLAN_BLOCK, round_up(m, 128))


def compute_cap(m: int, g: int, slack: float = 1.0) -> int:
    """Static per-group capacity: ``ceil(m/g)``, stretched by ``slack``.

    Integer mirror of :func:`repro.kernels.plan_encode.ref.compute_cap`
    (which lives beside jax imports); the reference implementation
    asserts parity in tests.
    """
    cap = max(1, -(-m // g))
    return min(m, int(-(-cap * slack // 1))) if slack > 1.0 else cap
