# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# NOTE: this module must stay importable WITHOUT jax — the static
# kernel auditor (repro.analysis.kernel_audit) and the per-package
# audit.py KernelSpec modules run in the jax-free CI analysis job, and
# they import repro.kernels.tiling through this package. jax imports
# live inside the functions that need them.
import contextlib as _contextlib

# Shared reference-impl mode for every Pallas kernel in this package:
# under plain jit, GSPMD cannot partition a pallas custom call — it
# replicates the kernel computation on every chip. On real TPUs kernels
# run under shard_map on local blocks; for CPU dry-runs the launcher
# lowers the mathematically identical jnp references instead, which GSPMD
# shards like any einsum. One switch covers flgw_matmul AND plan_encode so
# a lowering never mixes modes.
_REF_MODE: list = []


@_contextlib.contextmanager
def use_reference_impl():
    _REF_MODE.append(True)
    try:
        yield
    finally:
        _REF_MODE.pop()


def reference_impl_active() -> bool:
    return bool(_REF_MODE)


def tpu_compiler_params(**kwargs):
    """Mosaic compiler params across JAX versions.

    jax >= 0.5 exposes ``pltpu.CompilerParams``; 0.4.x calls the same class
    ``TPUCompilerParams``. All kernels route through this helper so they run
    on either.
    """
    from jax.experimental.pallas import tpu as _pltpu
    cls = getattr(_pltpu, "CompilerParams", None) \
        or getattr(_pltpu, "TPUCompilerParams")
    return cls(**kwargs)
