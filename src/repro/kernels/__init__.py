# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
from jax.experimental.pallas import tpu as _pltpu


def tpu_compiler_params(**kwargs):
    """Mosaic compiler params across JAX versions.

    jax >= 0.5 exposes ``pltpu.CompilerParams``; 0.4.x calls the same class
    ``TPUCompilerParams``. All kernels route through this helper so they run
    on either.
    """
    cls = getattr(_pltpu, "CompilerParams", None) \
        or getattr(_pltpu, "TPUCompilerParams")
    return cls(**kwargs)
