"""Pallas TPU kernel: OSEL mask encoding by index comparison.

OSEL observation 1: ``Mask[i,j] = (ig_idx[i] == og_idx[j])``. The FPGA
implements this with a comparator array fed by the two index lists; the TPU
equivalent is a VPU outer-equality over VMEM tiles of the index vectors —
O(M·N) 8-bit compares instead of the baseline's O(M·G·N) matmul, and no
M×G / G×N one-hot materialization.

The index vectors are carried as (M, 1) and (1, N) int32 so tiles respect
TPU (sublane, lane) layout. Output is uint8 (bitvector tiles).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tpu_compiler_params
# Shared with repro.kernels.osel_encode.audit so the audited grid is, by
# construction, the grid this wrapper builds.
from repro.kernels.tiling import round_up


def _encode_kernel(ig_ref, og_ref, mask_ref):
    ig = ig_ref[...]          # (bm, 1)
    og = og_ref[...]          # (1, bn)
    mask_ref[...] = (ig == og).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def encode_mask(ig_idx: jax.Array, og_idx: jax.Array, *, bm: int = 256,
                bn: int = 256, interpret: bool = False) -> jax.Array:
    """(M,) int32, (N,) int32 -> (M, N) uint8 mask."""
    m, n = ig_idx.shape[0], og_idx.shape[0]
    bm = min(bm, m)
    bn = min(bn, n)
    mp = round_up(m, bm)
    np_ = round_up(n, bn)
    ig2 = jnp.pad(ig_idx.astype(jnp.int32), (0, mp - m),
                  constant_values=-1)[:, None]
    og2 = jnp.pad(og_idx.astype(jnp.int32), (0, np_ - n),
                  constant_values=-2)[None, :]
    out = pl.pallas_call(
        _encode_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.uint8),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(ig2, og2)
    return out[:m, :n]
