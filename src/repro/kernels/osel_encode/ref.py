"""Oracle for the OSEL encode kernel: the paper's *baseline* encoder.

The baseline (LearningGroup §IV-C, "Baseline") generates the mask by the
original FLGW definition — materialize the one-hot selection matrices and
multiply: ``Mask = IS @ OS`` (an M×G×N matmul). OSEL replaces this with pure
index comparisons; the kernel must produce bit-identical masks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_mask_matmul(ig: jax.Array, og: jax.Array) -> jax.Array:
    """Mask via IS @ OS (the baseline's expensive path). ig: (M, G), og: (G, N)."""
    g = ig.shape[1]
    is_mat = jax.nn.one_hot(jnp.argmax(ig, axis=1), g, dtype=jnp.float32)
    os_mat = jax.nn.one_hot(jnp.argmax(og, axis=0), g, dtype=jnp.float32,
                            axis=0)
    return (is_mat @ os_mat) > 0.5


def ref_mask_indices(ig_idx: jax.Array, og_idx: jax.Array) -> jax.Array:
    """Mask via index equality (what the kernel computes)."""
    return ig_idx[:, None] == og_idx[None, :]


def ref_workloads(ig_idx: jax.Array, og_idx: jax.Array,
                  groups: int) -> jax.Array:
    """Per-row workload = nnz of the row's pattern = |{j: og_idx[j]==g_i}|."""
    hist = jnp.bincount(og_idx, length=groups)
    return hist[ig_idx].astype(jnp.int32)
