"""KernelSpec for the OSEL mask-encode kernel (jax-free).

The comparator-array encode is the simplest schedule in the repo — a 2-D
``(m-tile, n-tile)`` grid where every output tile is written exactly
once (no accumulation axes at all), which makes it the auditor's
disjointness base case: any revisit is a bug.
"""
from __future__ import annotations

from repro.analysis.kernel_audit import (GridCase, KernelSpec, Operand,
                                         register_kernel_spec)
from repro.kernels.tiling import round_up

INT32 = 4
UINT8 = 1


def _case(p: dict) -> GridCase:
    m, n = p["m"], p["n"]
    bm = min(p.get("bm", 256), m)
    bn = min(p.get("bn", 256), n)
    mp = round_up(m, bm)
    np_ = round_up(n, bn)
    return GridCase(
        label=f"m{m}_n{n}", grid=(mp // bm, np_ // bn),
        operands=(
            Operand("ig", (mp, 1), (bm, 1), lambda i, j: (i, 0), INT32),
            Operand("og", (1, np_), (1, bn), lambda i, j: (0, j), INT32),
            Operand("mask", (mp, np_), (bm, bn), lambda i, j: (i, j),
                    UINT8, role="out"),
        ),
        tags=("m_gt_4096",) if m > 4096 else (),
    )


register_kernel_spec(KernelSpec(
    name="osel_encode.encode_mask",
    module="repro.kernels.osel_encode.osel_encode",
    build=_case,
    corpus=(
        {"m": 48, "n": 64},
        {"m": 300, "n": 200},            # non-divisible, pads
        {"m": 1024, "n": 512},
        {"m": 4352, "n": 4352},          # crosses the old 4096 mark
    ),
    note="pure VPU outer-equality; zero accumulation axes",
))
