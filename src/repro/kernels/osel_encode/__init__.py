from repro.kernels.osel_encode.ops import osel_mask, reference_mask  # noqa: F401
from repro.kernels.osel_encode.osel_encode import encode_mask  # noqa: F401
