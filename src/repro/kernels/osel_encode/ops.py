"""Jit'd wrapper for the OSEL encode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.osel_encode.osel_encode import encode_mask
from repro.kernels.osel_encode import ref as _ref


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def osel_mask(ig_idx: jax.Array, og_idx: jax.Array,
              interpret: bool | None = None) -> jax.Array:
    """OSEL mask (uint8) from the grouping index vectors."""
    if interpret is None:
        interpret = default_interpret()
    return encode_mask(ig_idx, og_idx, interpret=interpret)


def reference_mask(ig: jax.Array, og: jax.Array) -> jax.Array:
    """Baseline IS @ OS mask (bool) from raw grouping matrices."""
    return _ref.ref_mask_matmul(ig, og)
