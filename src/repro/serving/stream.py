"""Open-loop synthetic request streams for the serving tier.

The arrival process reuses the MARL Traffic Junction idiom directly:
``traffic_junction.arrival_stream`` draws strictly-increasing entry
ticks with Geometric(p) gaps — a discrete open-loop Poisson analogue.
A higher ``p_arrive`` packs more requests into the same window (the
heavy-traffic regime the continuous-batching scheduler exists for);
prompt and generation lengths draw uniformly from caller ranges so the
workload has the ragged completion times static batching handles worst.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.marl.envs.traffic_junction import arrival_stream
from repro.serving.scheduler import Request

# Effectively "no feasibility squeeze": serving arrivals have no
# clear-the-junction deadline, so the stream's cap never binds.
_NO_CAP = 1 << 30


def synthetic_requests(seed: int, n: int, *, vocab: int,
                       p_arrive: float = 0.5,
                       prompt_len: Tuple[int, int] = (4, 12),
                       gen_len: Tuple[int, int] = (2, 16)) -> List[Request]:
    """Draw ``n`` open-loop requests: Geometric(p_arrive) arrival gaps,
    uniform prompt/generation lengths (inclusive ranges), uniform random
    prompt token ids over ``vocab``. Deterministic in ``seed``."""
    if n < 1:
        return []
    key = jax.random.PRNGKey(seed)
    ka, kp, kg, kt = jax.random.split(key, 4)
    arrivals = np.asarray(arrival_stream(ka, n, p_arrive, _NO_CAP))
    plens = np.asarray(jax.random.randint(
        kp, (n,), prompt_len[0], prompt_len[1] + 1))
    glens = np.asarray(jax.random.randint(
        kg, (n,), gen_len[0], gen_len[1] + 1))
    out = []
    for i in range(n):
        toks = jax.random.randint(jax.random.fold_in(kt, i),
                                  (int(plens[i]),), 0, vocab, jnp.int32)
        out.append(Request(rid=i, prompt=np.asarray(toks),
                           max_new_tokens=int(glens[i]),
                           arrival=int(arrivals[i])))
    return out


def max_seq_for(requests: List[Request]) -> int:
    """Smallest per-slot ring length that fits every request."""
    return max(len(r.prompt) + r.max_new_tokens for r in requests)
