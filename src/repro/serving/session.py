"""ServeSession — the one serving surface.

Before this module the serving API was scattered kwargs across three
modules: ``make_serve_step(refresh_plans=...)``, ``make_prefill_step(
plans=...)``, ``transformer.init_cache(params=...)`` and
``transformer.refresh_cache_plans``. A :class:`ServeSession` owns all of
it: the params version being served, the jitted prefill/decode steps, the
cache factory for both layouts (lockstep scalar-``pos`` and per-slot),
and one explicit ``plan_policy`` knob governing every plan-cache decision
— both the continuous-batching scheduler (``repro.serving.scheduler``)
and the lockstep path build on it. (The ``repro.train.step`` deprecation
shims that bridged the move are retired.)

Plan resolution goes through the process-wide cache
(``repro.serving.plan_cache``): concurrent sessions and requests against
the same params version share one certified PlanState — encode once per
params version, fan out to every in-flight request (the paper's
OSEL→core dataflow, at serving scope).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import encoder as planenc
from repro.core.flgw import FLGWConfig
from repro.models import transformer
from repro.serving import plan_cache
from repro.serving.steps import (check_plan_policy, make_decode_step,
                                 make_prefill_step)


class ServeSession:
    """One params version being served, with its plans and jitted steps.

    ``plan_policy``:

    * ``"certify"`` (default) — plans resolve through the process-wide
      plan cache at every request boundary (:meth:`refresh`,
      :meth:`update_params`, scheduler admission): one signature pass per
      boundary, a re-encode only when the grouping layout actually moved,
      and at most one encode per params version process-wide no matter
      how many concurrent consumers share it.
    * ``"trust"`` — plans are resolved once (here, and again at explicit
      :meth:`update_params` calls) and consumed unconditionally in
      between: zero signature work on the hot path. The caller promises
      params never move without an ``update_params``.
    * ``"off"`` — no cached plans: every grouped projection re-encodes
      per call. The unamortized baseline (and a no-op off the grouped
      path, where there are no plans to cache).
    """

    def __init__(self, cfg, params, *, plan_policy: str = "certify",
                 banded: bool = False, unroll_blocks: bool = False,
                 share_plans: bool = True, jit: bool = True,
                 debug_contracts: bool = False):
        self.cfg = cfg
        self.params = params
        self.plan_policy = check_plan_policy(plan_policy)
        # opt-in trace/compile contract (repro.analysis.contracts):
        # engines built on this session run their tick loop under
        # no_retrace — one compile per jitted step per shape, ever
        self.debug_contracts = debug_contracts
        self._share = share_plans
        self._grouped = cfg.flgw_groups > 1 and cfg.flgw_path == "grouped"
        self._slack = FLGWConfig(groups=cfg.flgw_groups,
                                 path=cfg.flgw_path).capacity_slack
        decode = make_decode_step(cfg, banded=banded,
                                  unroll_blocks=unroll_blocks)
        prefill = make_prefill_step(cfg, plan_policy=plan_policy,
                                    banded=banded)
        self._decode = jax.jit(decode) if jit else decode
        self._prefill = jax.jit(prefill) if jit else prefill
        self._wc_memo = None
        self.plans = self._resolve_plans()

    # -- plan resolution ---------------------------------------------------

    def _resolve_plans(self):
        """The session's PlanState under the current params — through the
        process-wide cache (one encode per params version) unless sharing
        is off; ``()`` under ``plan_policy="off"`` or off the grouped
        path (matching ``init_cache`` without params).

        The resolved state is *layout-only* — the shared cache is keyed
        by the layout signature, which never hashes weight values, so
        weight-bearing states must not live there (or in ``self.plans``,
        which concurrent sessions share by identity). The compact weights
        (``GroupPlan.wc``, the fused consume path's operand) are attached
        session-locally at the consumption points (:meth:`new_cache`,
        :meth:`refresh`, :meth:`prefill`) via :meth:`_attach`."""
        if self.plan_policy == "off" or not self._grouped:
            return ()
        encode = lambda: transformer.encode_plans(self.params, self.cfg)  # noqa: E731
        if not self._share:
            return encode()
        return plan_cache.shared_plans(self.params, encode=encode,
                                       slack=self._slack)

    def _attach(self, state):
        """Session-local OSEL handoff: this session's params compacted
        onto the shared layout (``GroupPlan.wc``), memoized so an
        unchanged (plans, params) pair costs zero re-gathers at request
        boundaries. Never mutates or replaces the shared ``state``."""
        if not state:
            return state
        memo = self._wc_memo
        if memo and memo[0] is state and memo[1] is self.params:
            return memo[2]
        attached = planenc.attach_compact(state, self.params)
        self._wc_memo = (state, self.params, attached)
        return attached

    def update_params(self, params) -> None:
        """Publish a new params version to the session (online tuning).

        The explicit boundary for every policy: ``certify`` and ``trust``
        both re-resolve the PlanState here (through the shared cache, so
        a version other sessions already serve costs one signature pass,
        zero encodes). Caches handed out earlier still hold the old
        PlanState — pass them through :meth:`refresh` (certify) or
        rebuild them (trust).
        """
        self.params = params
        self.plans = self._resolve_plans()

    def refresh(self, cache: dict) -> dict:
        """Request-boundary certification of a cache's PlanState.

        Under ``certify``, re-resolves the plans against the session's
        current params and swaps them into the cache (signature pass per
        call; encode only on a genuinely new layout). Under ``trust`` and
        ``off`` this is a no-op — that is the policy's meaning.
        """
        if self.plan_policy != "certify" or not self._grouped:
            return cache
        if not isinstance(cache.get("plans"), planenc.PlanState):
            return cache
        self.plans = self._resolve_plans()
        return dict(cache, plans=self._attach(self.plans))

    # -- caches ------------------------------------------------------------

    def new_cache(self, batch: int, max_seq: int, dtype=None, *,
                  per_slot: bool = False) -> dict:
        """Decode cache carrying the session's plans per ``plan_policy``.

        ``per_slot=True`` allocates the continuous-batching layout (one
        stream offset per batch row — see ``transformer.init_cache``).
        """
        cache = transformer.init_cache(self.cfg, batch, max_seq, dtype,
                                       per_slot=per_slot)
        cache["plans"] = self._attach(self.plans) if self._grouped and \
            self.plan_policy != "off" else ()
        return cache

    # -- steps -------------------------------------------------------------

    def decode(self, cache: dict, tokens, positions):
        """One greedy decode step: ``(next_tok, cache)``."""
        return self._decode(self.params, cache, tokens, positions)

    def prefill(self, batch, plans=...):
        """Full-sequence prefill -> last-position logits. ``plans``
        defaults to the session's PlanState (policy-resolved); pass
        explicitly (e.g. ``cache["plans"]``) to override."""
        if plans is ...:
            plans = self._attach(self.plans) if self._grouped and \
                self.plan_policy != "off" else None
        if plans == ():
            plans = None
        return self._prefill(self.params, batch, plans)

    def greedy_positions(self, batch: int, pos: int):
        """(batch, 1) positions column for a lockstep decode step."""
        return jnp.full((batch, 1), pos, jnp.int32)
