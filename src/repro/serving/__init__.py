"""Plan-aware serving tier: one session API, continuous batching on top.

Surface:

* :class:`~repro.serving.session.ServeSession` — the unified serving
  object (params version + jitted steps + caches + the ``plan_policy``
  knob: ``"certify" | "trust" | "off"``).
* :class:`~repro.serving.scheduler.Engine` — continuous-batching request
  scheduler (slot admission, prefill/decode interleaving); its
  ``admission="lockstep"`` mode is the static-batching baseline.
* ``repro.serving.plan_cache`` — process-wide PlanState cache keyed by
  the grouping-layout signature: one encode per params version, shared
  by every concurrent request and session.
* :func:`~repro.serving.stream.synthetic_requests` — open-loop Geometric
  load generator (the Traffic Junction ``arrival_stream`` idiom).
* ``repro.serving.steps`` — the jittable decode/prefill factories the
  session builds on (the sole surface: the PR-6 ``repro.train.step``
  deprecation shims are retired).
"""
from repro.serving import plan_cache  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    ADMISSION_MODES,
    Engine,
    Request,
    RequestRecord,
    ServeReport,
)
from repro.serving.session import ServeSession  # noqa: F401
from repro.serving.steps import (  # noqa: F401
    PLAN_POLICIES,
    make_decode_step,
    make_prefill_step,
)
from repro.serving.stream import max_seq_for, synthetic_requests  # noqa: F401
