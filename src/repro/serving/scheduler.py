"""Continuous-batching request scheduler over a per-slot decode cache.

The lockstep loop (``examples/serve.py`` pre-PR-6) runs a fixed batch of
requests from shared prefill to shared completion: every slot waits for
the slowest member, and arrivals wait for the whole batch to drain. This
scheduler is the real thing — iteration-level scheduling in the Orca /
continuous-batching sense, adapted to the repo's single jitted step:

* **slot-based admission** — the decode batch is ``capacity`` slots; a
  request occupies one slot from admission to completion and a freed slot
  is recycled (``transformer.reset_slots``) for the next queued request
  *mid-flight*, while the other slots keep decoding;
* **prefill/decode interleaving at token granularity** — every engine
  step feeds each active slot one token: the next prompt token while the
  slot is prefilling, its previously-generated token once decoding. One
  compiled program serves both phases, so a fresh prefill rides the same
  step that advances its neighbours' decodes;
* **plan-aware admission** — admission is a request boundary: under
  ``plan_policy="certify"`` the session re-certifies the cache's
  PlanState there (through the process-wide plan cache, so N concurrent
  requests against one params version share ONE encode).

``admission="lockstep"`` restricts admission to an all-slots-free engine
— the static-batching baseline, running the *same* jitted step at the
same capacity, so a throughput comparison isolates exactly the
scheduling discipline (benchmarks/fig14_serving.py).

The engine clock is the **tick**: one compute step = one tick, and the
clock fast-forwards over genuinely idle stretches (nothing active, next
arrival in the future) without burning compute. Request arrivals are
open-loop tick offsets (``repro.serving.stream`` draws them Geometric,
the ``traffic_junction.arrival_stream`` idiom) — arrival never waits on
service, so queueing delay shows up in the latency numbers instead of
back-pressuring the generator.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts
from repro.models import transformer

ADMISSION_MODES = ("continuous", "lockstep")


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request: prompt in, ``max_new_tokens`` greedy out."""
    rid: int
    prompt: np.ndarray            # (P,) int32 token ids, P >= 1
    max_new_tokens: int
    arrival: int = 0              # tick at which the request becomes visible

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")


@dataclasses.dataclass
class RequestRecord:
    """Per-request lifecycle + output, as the engine observed it."""
    rid: int
    arrival: int                       # tick the request became visible
    prompt_len: int = 0
    admitted: int = -1                 # tick it entered a slot
    first_token: int = -1              # tick its first generated token landed
    completed: int = -1                # tick its last token landed
    slot: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    arrival_wall: float = float("nan")
    completed_wall: float = float("nan")

    @property
    def latency_ticks(self) -> int:
        return self.completed - self.arrival

    @property
    def latency_s(self) -> float:
        return self.completed_wall - self.arrival_wall


@dataclasses.dataclass
class ServeReport:
    """Aggregate of one engine run (``Engine.run``)."""
    admission: str
    capacity: int
    steps: int                         # compute steps executed
    wall_s: float
    generated_tokens: int
    records: List[RequestRecord]

    @property
    def tokens_per_s(self) -> float:
        return self.generated_tokens / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def slot_utilization(self) -> float:
        """Fraction of slot-steps that fed a live request (prefill or
        decode) — the number continuous batching exists to raise. A
        request occupies its slot for ``prompt_len + generated - 1``
        steps (the last prompt token's step already yields the first
        generated token)."""
        if self.steps == 0:
            return 0.0
        busy = sum(r.prompt_len + len(r.tokens) - 1
                   for r in self.records if r.completed >= 0)
        return busy / (self.steps * self.capacity)

    def latency_percentiles(self, qs=(50, 99)) -> dict:
        lats = [r.latency_s for r in self.records if r.completed >= 0]
        ticks = [r.latency_ticks for r in self.records if r.completed >= 0]
        out = {}
        for q in qs:
            out[f"p{q}_s"] = float(np.percentile(lats, q)) if lats else None
            out[f"p{q}_ticks"] = (float(np.percentile(ticks, q))
                                  if ticks else None)
        return out

    def summary(self) -> dict:
        lat = self.latency_percentiles()
        return {"admission": self.admission, "capacity": self.capacity,
                "requests": len(self.records), "steps": self.steps,
                "wall_s": self.wall_s,
                "generated_tokens": self.generated_tokens,
                "tokens_per_s": self.tokens_per_s,
                "slot_utilization": self.slot_utilization, **lat}


@dataclasses.dataclass
class _Slot:
    """Host-side state of one occupied batch row."""
    req: Request
    record: RequestRecord
    fed: int = 0                  # tokens fed so far (prompt first)
    gen: int = 0                  # tokens generated so far

    @property
    def done_prefill(self) -> bool:
        return self.fed >= len(self.req.prompt)


class Engine:
    """Slot-based serving engine over one :class:`~repro.serving.session.
    ServeSession`.

    ``capacity`` is the decode-batch width (number of slots); ``max_seq``
    bounds one request's prompt+generation (the per-slot ring length).
    ``admission`` picks the scheduling discipline (see module docstring).
    """

    def __init__(self, session, capacity: int, max_seq: int, *,
                 admission: str = "continuous",
                 debug_contracts: Optional[bool] = None):
        if admission not in ADMISSION_MODES:
            raise ValueError(
                f"admission must be one of {ADMISSION_MODES}, "
                f"got {admission!r}")
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.session = session
        self.capacity = capacity
        self.max_seq = max_seq
        self.admission = admission
        # opt-in recompile contract; None inherits the session's flag
        self.debug_contracts = (
            getattr(session, "debug_contracts", False)
            if debug_contracts is None else debug_contracts)
        self._reset = jax.jit(transformer.reset_slots)

    # -- one run -----------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> ServeReport:
        """Serve ``requests`` to completion; returns the run's report.

        The request list is an open-loop schedule: each request becomes
        visible at its ``arrival`` tick regardless of engine progress.
        Deterministic given the session's params and the request list.

        With ``debug_contracts`` on (here or on the session), the whole
        run executes under :func:`repro.analysis.contracts.no_retrace`:
        the decode step, slot reset and plan certification may each
        compile once — a second compile of any of them mid-run (a shape
        instability, a traced flag, a lost jit cache) raises
        :class:`~repro.analysis.contracts.RetraceError` instead of
        silently stalling the tick loop.
        """
        if self.debug_contracts:
            with contracts.no_retrace(label="Engine.run"):
                return self._run(requests)
        return self._run(requests)

    def _run(self, requests: Sequence[Request]) -> ServeReport:
        for r in requests:
            need = len(r.prompt) + r.max_new_tokens
            if need > self.max_seq:
                raise ValueError(
                    f"request {r.rid} needs {need} cache positions, "
                    f"engine max_seq is {self.max_seq}")
        b = self.capacity
        pending = deque(sorted(requests, key=lambda r: (r.arrival, r.rid)))
        records = {r.rid: RequestRecord(rid=r.rid, arrival=r.arrival,
                                        prompt_len=len(r.prompt))
                   for r in requests}
        order = [r.rid for r in requests]
        unstamped = deque(sorted(records.values(), key=lambda c: c.arrival))

        cache = self.session.new_cache(b, self.max_seq, per_slot=True)
        slots: List[Optional[_Slot]] = [None] * b
        pos = np.zeros(b, np.int64)    # host mirror of cache["pos"]
        tick = 0
        steps = 0
        generated = 0
        wall0 = time.perf_counter()

        # Deferred token plumbing: the jitted step's outputs stay on
        # device. A decoding slot's next input is last step's output fed
        # back device-side (jnp.where against the host prompt column), and
        # token VALUES only reach the host in one batched fetch per
        # completion boundary — the tick loop itself never blocks on the
        # device (the marl scan's once-per-window host-fetch discipline;
        # every lifecycle decision below runs on host counters alone).
        prev_out = None                       # (b, 1) last step's tokens
        outs_dev: List[jax.Array] = []        # per-step (b,) token columns
        events: List[Tuple[RequestRecord, int, int]] = []  # (rec, step, i)
        outs_base = 0                         # step index of outs_dev[0]

        def now() -> float:
            return time.perf_counter() - wall0

        def stamp_arrivals():
            t = now()
            while unstamped and unstamped[0].arrival <= tick:
                unstamped.popleft().arrival_wall = t

        def flush_tokens():
            """One host fetch for every step since the last boundary."""
            nonlocal outs_base
            if events:
                stacked = np.asarray(jnp.stack(outs_dev))     # 1 sync
                for rec, step_idx, slot_i in events:
                    rec.tokens.append(
                        int(stacked[step_idx - outs_base, slot_i]))
                events.clear()
            outs_dev.clear()
            outs_base = steps

        stamp_arrivals()
        while pending or any(slots):
            # -- clock: fast-forward genuinely idle stretches -------------
            if not any(slots) and pending and pending[0].arrival > tick:
                tick = pending[0].arrival
                stamp_arrivals()

            # -- admission ------------------------------------------------
            can_admit = (self.admission == "continuous"
                         or not any(slots))
            admitted = []
            if can_admit:
                for i in range(b):
                    if slots[i] is not None:
                        continue
                    if not pending or pending[0].arrival > tick:
                        break
                    req = pending.popleft()
                    rec = records[req.rid]
                    rec.admitted = tick
                    rec.slot = i
                    slots[i] = _Slot(req=req, record=rec)
                    admitted.append(i)
            if admitted:
                # request boundary: certify the cache's PlanState (policy-
                # dependent; under "certify" this resolves through the
                # process-wide plan cache — shared encode, not per-request)
                cache = self.session.refresh(cache)
                mask = np.zeros(b, bool)
                mask[admitted] = True
                cache = self._reset(cache, mask)
                pos[admitted] = 0

            # -- one unified prefill/decode step --------------------------
            tok = np.zeros(b, np.int32)
            fb = np.zeros(b, bool)     # rows fed from device feedback
            for i, s in enumerate(slots):
                if s is None:
                    continue
                if s.done_prefill:
                    fb[i] = True       # input = last step's generated token
                else:
                    tok[i] = int(s.req.prompt[s.fed])
            tok_dev = jnp.asarray(tok[:, None])
            if prev_out is not None and fb.any():
                tok_dev = jnp.where(jnp.asarray(fb[:, None]), prev_out,
                                    tok_dev)
            next_tok, cache = self.session.decode(
                cache, tok_dev,
                jnp.asarray(pos[:, None].astype(np.int32)))
            prev_out = next_tok
            outs_dev.append(next_tok[:, 0])
            steps += 1
            tick += 1
            pos += 1           # the step advanced every row's device offset
            stamp_arrivals()

            # -- bookkeeping / retirement (host counters only) ------------
            completed_now = []
            for i, s in enumerate(slots):
                if s is None:
                    continue
                s.fed += 1
                if s.done_prefill:     # this step yielded a generated token
                    events.append((s.record, steps - 1, i))
                    s.gen += 1
                    generated += 1
                    if s.record.first_token < 0:
                        s.record.first_token = tick
                    if s.gen >= s.req.max_new_tokens:
                        s.record.completed = tick
                        completed_now.append(s.record)
                        slots[i] = None
            if completed_now:
                # completion boundary: materialize the window (blocks
                # until the device caught up) and stamp honest wall times
                flush_tokens()
                t = now()
                for rec in completed_now:
                    rec.completed_wall = t

        flush_tokens()
        wall = time.perf_counter() - wall0
        return ServeReport(admission=self.admission, capacity=b,
                           steps=steps, wall_s=wall,
                           generated_tokens=generated,
                           records=[records[rid] for rid in order])
