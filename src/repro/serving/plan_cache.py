"""Process-wide PlanState cache keyed by the grouping-layout signature.

The paper's OSEL argument is that sparse metadata is cheap to produce
*once* and amortize across many consumers. PR 4/5 proved that per request
batch (the PlanState beside one KV cache); this module is the serving
analogue at process scope: every :class:`~repro.serving.session.
ServeSession`, and every request a scheduler admits, resolves its plans
here — so N concurrent requests (or sessions) against the same params
version share ONE certified encode instead of paying
``refresh_cache_plans`` each (the trace-count guarantee pinned in
tests/test_serving.py).

The key is ``(structure fingerprint, capacity slack, uint32 layout
signature)``: the signature (:func:`repro.core.encoder.plan_signature`)
changes whenever a fresh encode would differ bitwise, and the structure
fingerprint (layer paths + grouping-matrix shapes) disambiguates distinct
models that happen to collide on the 32-bit hash. Lookups cost one
signature pass (~half an encode); only misses encode. A small LRU bound
keeps online-tuning churn (a new params version per publish) from growing
the cache without limit.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, Optional

import jax

from repro.core import encoder as planenc
from repro.core import grouped
from repro.core.grouped import iter_flgw_layers

# Request boundaries pay one signature pass each; eagerly that is a long
# chain of tiny dispatches (~30x one decode step on CPU), jitted it is
# one fused program — the difference between admission overhead drowning
# the continuous-batching win and not (benchmarks/fig14_serving.py).
_jit_signature = jax.jit(planenc.plan_signature)

# A handful of live params versions is the realistic ceiling (serving
# typically runs one, online tuning a rolling window of two or three).
MAX_ENTRIES = 8

_LOCK = threading.Lock()
_CACHE: OrderedDict[tuple, planenc.PlanState] = OrderedDict()
_STATS = {"hits": 0, "misses": 0, "encodes": 0}


def structure_key(params: dict) -> tuple:
    """Host-side fingerprint of a param tree's FLGW structure: the layer
    paths and grouping-matrix shapes — metadata only, no device work."""
    return tuple((path, tuple(p["ig"].shape), tuple(p["og"].shape))
                 for path, p in iter_flgw_layers(params))


def shared_plans(params: dict, *, encode: Callable[[], planenc.PlanState],
                 slack: float = 1.0,
                 sig: Optional[int] = None) -> planenc.PlanState:
    """Resolve the PlanState of ``params`` through the process-wide cache.

    ``encode`` builds the PlanState on a miss (the stack's own entry
    point — e.g. ``lambda: transformer.encode_plans(params, cfg)``); its
    result must carry the signature of ``params``. ``sig`` short-circuits
    the signature pass when the caller already computed it.

    Returns the one PlanState every concurrent consumer of this params
    version shares. Thread-safe; the encode itself runs outside the lock
    (two racing first-lookups may both encode — the second write wins,
    correctness is unaffected since both are bitwise-identical).
    """
    if sig is None:
        sig = int(_jit_signature(params))
    key = (structure_key(params), float(slack), int(sig))
    with _LOCK:
        state = _CACHE.get(key)
        if state is not None:
            _CACHE.move_to_end(key)
            _STATS["hits"] += 1
            return state
        _STATS["misses"] += 1
    state = encode()
    if not isinstance(state, planenc.PlanState):
        raise TypeError(
            f"encode() must return a PlanState, got {type(state).__name__}")
    # The key hashes the grouping *layout* only — weight values are
    # invisible to it, so a weight-bearing state (attached compact
    # weights, GroupPlan.wc) cached here would leak one params version's
    # weights into every other version with the same layout. Strip them:
    # consumers attach wc against their own params after the fetch
    # (ServeSession._attach).
    if grouped.has_compact(state.plans):
        state = state._replace(plans=grouped.strip_compact(state.plans))
    with _LOCK:
        _STATS["encodes"] += 1
        _CACHE[key] = state
        _CACHE.move_to_end(key)
        while len(_CACHE) > MAX_ENTRIES:
            _CACHE.popitem(last=False)
    return state


def stats() -> dict:
    with _LOCK:
        return dict(_STATS, entries=len(_CACHE))


def clear() -> None:
    """Drop every cached PlanState and zero the counters (tests; or after
    a params schema change that invalidates structure fingerprints)."""
    with _LOCK:
        _CACHE.clear()
        for k in _STATS:
            _STATS[k] = 0
