"""Serving step factories — the functions the serving tier jits.

These were previously scattered across ``repro.train.step``
(``make_serve_step`` / ``make_prefill_step`` — kept there as deprecated
shims) and ``repro.models.transformer`` (``init_cache(params=...)`` /
``refresh_cache_plans``). The consolidated surface is
:class:`repro.serving.session.ServeSession`; these factories are the
session's building blocks, exposed for callers that manage their own jit
boundary (the dry-run compiles them against abstract shardings).

The one policy knob is ``plan_policy`` (see :data:`PLAN_POLICIES`):

* ``"certify"`` — cached PlanStates are signature-checked at request
  boundaries and re-encoded iff the grouping layout moved (safe under
  online tuning; the default).
* ``"trust"``  — cached PlanStates are consumed unconditionally: zero
  signature work, caller promises params are frozen between explicit
  ``ServeSession.update_params`` calls.
* ``"off"``    — no plan caching anywhere: grouped projections re-encode
  per call (the unamortized fallback — mostly a measurement baseline).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import encoder as planenc
from repro.models import transformer

PLAN_POLICIES = ("certify", "trust", "off")


def check_plan_policy(plan_policy: str) -> str:
    if plan_policy not in PLAN_POLICIES:
        raise ValueError(
            f"plan_policy must be one of {PLAN_POLICIES}, got "
            f"{plan_policy!r}")
    return plan_policy


def make_decode_step(cfg, *, banded: bool = False,
                     unroll_blocks: bool = False,
                     certify_each_step: bool = False):
    """Returns ``decode_step(params, cache, tokens, positions)`` —
    one-token greedy decode against the KV/SSM caches.

    Works against both cache layouts: the lockstep scalar-``pos`` cache
    and the per-slot (``init_cache(per_slot=True)``) cache the
    continuous-batching scheduler drives, where every batch row holds its
    own stream offset and ``positions`` carries per-row values.

    On the FLGW grouped path the cache's PlanState (parked beside the
    KV/SSM buffers) is consumed by every projection — zero ``make_plan``
    work per step. ``certify_each_step=True`` builds a signature check
    into every step (the old ``make_serve_step(refresh_plans=True)``) —
    for servers that interleave tuning and decoding with no request
    boundary to hook; it costs ~half an encode per step, so request-level
    certification (``ServeSession.refresh`` / admission) is the default.
    """

    def decode_step(params, cache, tokens, positions):
        if certify_each_step:
            cache = transformer.refresh_cache_plans(params, cfg, cache)
        logits, _, cache = transformer.lm_apply(
            params, cfg, tokens, positions, cache=cache, banded=banded,
            remat=False, unroll_blocks=unroll_blocks)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return decode_step


def make_prefill_step(cfg, *, plan_policy: str = "certify",
                      banded: bool = False, q_chunk: Optional[int] = None,
                      ssd_unroll: bool = False, unroll_blocks: bool = False,
                      attn_identity: bool = False):
    """Returns ``prefill(params, batch, plans=None) -> last logits`` —
    the full-sequence forward of the prefill shape cells.

    Plan handling follows ``plan_policy``:

    * ``"certify"`` — a caller-supplied PlanState (e.g. the plans cached
      beside a KV cache) is certified against the current params: one
      signature pass, a re-encode iff the grouping layout moved. With no
      plans, encodes once for the whole forward.
    * ``"trust"``   — caller plans are consumed as-is (no signature work);
      with no plans, encodes once.
    * ``"off"``     — ignores caller plans; every grouped projection
      re-encodes per call.
    """
    check_plan_policy(plan_policy)
    from repro.train.step import pick_q_chunk

    def prefill_step(params, batch, plans=None):
        s = batch["tokens"].shape[1]
        qc = q_chunk or pick_q_chunk(s)
        if plan_policy == "off":
            plans = None
        elif plans is None:
            # empty PlanState (a no-op) off the grouped path
            plans = transformer.encode_plans(params, cfg)
        elif (plan_policy == "certify"
              and isinstance(plans, planenc.PlanState) and plans.plans):
            plans = planenc.refresh_if_stale(
                params, plans,
                encode=lambda: transformer.encode_plans(params, cfg))
        hidden, _, _ = transformer.lm_apply(
            params, cfg, batch["tokens"], batch["positions"],
            patch_embeds=batch.get("patch_embeds"),
            frames=batch.get("frames"),
            q_chunk=qc, banded=banded, remat=False, return_hidden=True,
            ssd_unroll=ssd_unroll, unroll_blocks=unroll_blocks,
            moe_dropless=True, attn_identity=attn_identity, plans=plans)
        # Only the last position's logits are needed to start decoding.
        from repro.models.layers import softcap, unembed
        logits = unembed(params["embed"], hidden[:, -1:])
        return softcap(logits.astype(jnp.float32), cfg.logit_softcap)

    return prefill_step
