from repro.optim.optimizers import (adamw, adamw_init, rmsprop, rmsprop_init,
                                    global_norm, clip_by_global_norm)
from repro.optim.compression import (topk_compress, topk_decompress,
                                     CompressionState, compressed_allreduce)

__all__ = [
    "adamw", "adamw_init", "rmsprop", "rmsprop_init", "global_norm",
    "clip_by_global_norm", "topk_compress", "topk_decompress",
    "CompressionState", "compressed_allreduce",
]
