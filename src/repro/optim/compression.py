"""Top-k gradient compression with error feedback for the DP all-reduce.

At 1000+ nodes the data-parallel gradient all-reduce is the dominant
inter-pod collective. ``compressed_allreduce`` sends only the top-k
magnitude entries of each gradient leaf (k = ratio · size) and accumulates
the residual locally (error feedback, Karimireddy et al. '19), which keeps
SGD convergence while cutting DP collective bytes by ``1/ratio``.

This composes with the paper's technique rather than replacing it: FLGW
already zeroes (1 − 1/G) of each weight gradient *exactly* (masked entries
get no gradient from the masked forward), so with grouping enabled the
natural ratio is ≈ 1/G and top-k mostly selects the surviving entries —
the sparsity the paper creates for compute is reused for communication.

The collective itself is expressed with ``jax.lax.psum`` inside shard_map
(dense on the gathered top-k union), so XLA can overlap it with backward
compute. For pjit-based steps we expose the simpler dense path and use
compression only on the explicit shard_map DP path (runtime/elastic).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any      # residual tree (error feedback memory), f32


def compression_init(params) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))


def topk_compress(g: jax.Array, ratio: float):
    """Keep the top-k |values| of a flat leaf. Returns (values, indices, k).

    Static k = ceil(ratio · size), so shapes are jit-stable.
    """
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(ratio * flat.shape[0]))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32), k


def topk_decompress(values: jax.Array, indices: jax.Array,
                    shape, dtype=jnp.float32) -> jax.Array:
    size = 1
    for s in shape:
        size *= s
    return (jnp.zeros((size,), dtype).at[indices].set(values)
            .reshape(shape))


def compressed_allreduce(grads, state: CompressionState, axis_name,
                         *, ratio: float = 0.1):
    """Error-feedback top-k all-reduce over ``axis_name`` (inside shard_map).

    Each shard adds its residual, selects local top-k, and psums the
    *dense scatter* of its sparse selection (the union of per-shard top-k
    supports). Residual keeps what was not sent. Returns
    (reduced_grads, new_state).
    """

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        vals, idx, _ = topk_compress(g32, ratio)
        sent = topk_decompress(vals, idx, g.shape)
        new_e = g32 - sent
        reduced = jax.lax.pmean(sent, axis_name)
        return reduced.astype(g.dtype), new_e

    out = jax.tree.map(one, grads, state.error)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), CompressionState(error=pick(1))
