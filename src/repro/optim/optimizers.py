"""Optimizers, written as pure pytree transforms (no optax dependency).

``rmsprop`` is the paper's optimizer (RMSprop, lr=1e-3, §IV-A); ``adamw``
serves the LM training path. Both keep f32 accumulator state regardless of
the (possibly bf16) parameter dtype — the "f32 master state" half of the
mixed-precision recipe; parameters themselves stay in their stored dtype
with the update computed in f32 and cast back.

State layout mirrors the parameter pytree (one accumulator leaf per param
leaf), so the same NamedSharding tree shards params and optimizer state
identically — required for the multi-pod dry-run to fit.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Utilities
# ---------------------------------------------------------------------------

def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    """Scale the whole gradient tree so its global norm is ≤ max_norm."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# RMSprop (paper §IV-A: lr = 1e-3)
# ---------------------------------------------------------------------------

def rmsprop_init(params):
    """Square-average accumulator, f32, same tree as params."""
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)


def rmsprop(params, grads, state, *, lr: float = 1e-3, decay: float = 0.99,
            eps: float = 1e-8):
    """One RMSprop step. Returns (new_params, new_state)."""

    def upd(p, g, s):
        g32 = g.astype(jnp.float32)
        s = decay * s + (1.0 - decay) * jnp.square(g32)
        step = lr * g32 / (jnp.sqrt(s) + eps)
        return (p.astype(jnp.float32) - step).astype(p.dtype), s

    out = jax.tree.map(upd, params, grads, state)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_state = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    return new_params, new_state


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    mu: Any         # first moment, f32 tree
    nu: Any         # second moment, f32 tree
    count: jax.Array


def adamw_init(params) -> AdamWState:
    z = lambda p: jnp.zeros_like(p, jnp.float32)
    return AdamWState(mu=jax.tree.map(z, params),
                      nu=jax.tree.map(z, params),
                      count=jnp.zeros((), jnp.int32))


def adamw(params, grads, state: AdamWState, *, lr: float = 3e-4,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1):
    """One AdamW step. Returns (new_params, new_state)."""
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g32
        v = b2 * v + (1.0 - b2) * jnp.square(g32)
        step = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - step - lr * weight_decay * p32
        return p32.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), AdamWState(mu=pick(1), nu=pick(2), count=count)
