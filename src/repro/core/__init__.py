"""Core library: the paper's contribution (FLGW pruning + OSEL + balancing)."""
from repro.core.flgw import (  # noqa: F401
    FLGWConfig, init_grouping, grouping_indices, mask_from_indices,
    mask_ste, flgw_linear, mask_sparsity, selection_matrices,
)
from repro.core.grouped import (  # noqa: F401
    GroupPlan, balanced_assign, make_plan, transpose_plan, grouped_apply,
)
from repro.core.encoder import (  # noqa: F401
    PlanState, encode_plans, maybe_refresh, plan_signature,
)
from repro.core import osel  # noqa: F401
