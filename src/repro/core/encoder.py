"""Device-resident plan-encoder subsystem — one OSEL analogue for all stacks.

The paper's OSEL encodes the FLGW mask *once per iteration* into compact
sparse metadata the whole step reuses (§III-B). This module is that
encoder as a first-class subsystem shared by every workload (the MARL
engine and the LM/transformer stack), instead of per-caller helpers:

* :class:`PlanState` — the cached metadata: one :class:`~repro.core.grouped.
  GroupPlan` per FLGW-carrying projection (nested dict mirroring the param
  tree; stacked/scanned layers get stacked plans) plus a ``sig`` hash of
  the ig/og argmaxes the plans were encoded from.
* :func:`encode_plans` — one encoding pass over any param tree. The
  balanced assignment itself runs on the ``plan_encode`` Pallas kernel
  (``repro.kernels.plan_encode``).
* :func:`maybe_refresh` — the refresh policy, usable under trace
  (``lax.cond`` inside) and from host loops alike:

  - ``"period"``    — re-encode every ``schedule.refresh_every`` steps
    (the PR-2 behavior; the paper's once-per-iteration encode at k=1);
  - ``"on_change"`` — re-encode only when the balanced-deal layout
    actually moved (detected via ``sig``, which hashes the ig/og argmaxes
    *and* the within-group confidence ranks — so ``slack > 1`` spill-order
    drift fires a refresh too, not just argmax flips). The paper's masks
    churn early and freeze late, so change-driven refresh matches per-step
    re-encoding exactly while masks move and costs one signature pass —
    one sort + a segmented count per side, ~half an encode — once they
    freeze. Exactness frontier; a coarse ``"period"`` buys more
    throughput with the staleness it tolerates (fig12);
  - ``"hybrid"``    — on change, with ``refresh_every`` as a staleness
    bound (belt-and-suspenders against hash collisions; before the
    signature hashed placement ranks it was the only mode that bounded
    spill-order staleness).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import grouped

REFRESH_MODES = ("period", "on_change", "hybrid")

_MIX = 2654435761        # Knuth's multiplicative-hash constant (odd)
_FOLD = 1000003          # layer-fold multiplier (odd)


class PlanState(NamedTuple):
    """Cached sparse metadata of a param tree + the hash it was built from.

    ``plans`` mirrors the params nesting with a GroupPlan at every
    FLGW-carrying projection (``{}`` when the grouped path is off — the
    empty state keeps training-loop carries structurally uniform).
    ``sig`` is a uint32 hash of the grouping layout (:func:`plan_signature`):
    any single argmax flip — and any within-group confidence reorder, which
    moves slots/spills under ``slack > 1`` — changes it, so ``sig``
    equality certifies the cached plans are still bitwise-identical to a
    fresh encode of the current grouping matrices.
    """
    plans: Any
    sig: jax.Array

    def __bool__(self) -> bool:           # truthiness == "has any plans"
        return bool(self.plans)


def empty_state() -> PlanState:
    return PlanState({}, jnp.zeros((), jnp.uint32))


def _layout_ranks(scores: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(pref, rank) of one grouping side; ``scores``: (..., M, G).

    ``pref`` is each item's argmax group; ``rank`` is the item's position
    *within its preferred group* under the (strength desc, index asc)
    order — together they determine the balanced deal's placement order
    (pref asc, strength desc, index asc; see ``plan_encode.ref``) and
    therefore the compact layout bitwise: a strength reorder inside one
    group permutes slots and redirects which overflow item spills
    (``slack > 1``), even when no argmax flips.

    Cost matters — on_change evaluates this every step, so it must stay
    well under one encode: one stable argsort, a segmented count via
    cumsum (O(M·G)), and a scatter back to item order — cheaper than the
    encode's own lexsort-equivalent two-sort pipeline.
    """
    g = scores.shape[-1]
    pref = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    strength = jnp.max(scores, axis=-1)
    order = jnp.argsort(-strength, axis=-1, stable=True)   # ties: index asc
    pref_sorted = jnp.take_along_axis(pref, order, axis=-1)
    # Within-group rank of each sorted position: running count of earlier
    # same-group items in strength order.
    cnt = jnp.cumsum(jax.nn.one_hot(pref_sorted, g, dtype=jnp.int32),
                     axis=-2)
    rank_sorted = jnp.take_along_axis(
        cnt, pref_sorted[..., None], axis=-1)[..., 0] - 1
    rank = jnp.put_along_axis(jnp.zeros_like(rank_sorted), order,
                              rank_sorted, axis=-1, inplace=False)
    return pref, rank


def plan_signature(params: dict) -> jax.Array:
    """uint32 hash of every FLGW layer's balanced-deal layout.

    Hashes, per layer and grouping side, the argmax index vector *and*
    the placement-rank vector (:func:`_layout_ranks`), so the signature
    changes iff a fresh encode would produce a bitwise-different plan —
    argmax flips and ``slack > 1`` spill-order drift alike. Each value
    gets an odd per-position weight and layers fold with an odd
    multiplier, so any single change moves the hash
    (odd · nonzero ≠ 0 mod 2^32); simultaneous multi-change cancellation
    is the only collision mode and is vanishingly unlikely.
    """
    h = jnp.zeros((), jnp.uint32)
    salt = 1
    for _, p in grouped.iter_flgw_layers(params):
        for scores in (p["ig"], jnp.swapaxes(p["og"], -1, -2)):
            for idx in _layout_ranks(scores):
                v = idx.astype(jnp.uint32).reshape(-1)
                w = (jnp.arange(v.shape[0], dtype=jnp.uint32)
                     * jnp.uint32(_MIX) + jnp.uint32(salt)) | jnp.uint32(1)
                h = h * jnp.uint32(_FOLD) + jnp.sum((v + jnp.uint32(1)) * w)
                salt += 2
    return h


def encode_plans(params: dict, cfg) -> PlanState:
    """One encoding pass over a param tree — plans + their signature.

    ``cfg`` is the layer's :class:`~repro.core.flgw.FLGWConfig` (anything
    with ``capacity_slack``). Handles flat trees (MARL/IC3Net) and stacked
    scan-layer trees (the LM decoder) alike — see
    :func:`repro.core.grouped.encode_plans` for the per-layer walk.
    """
    return PlanState(grouped.encode_plans(params, cfg),
                     plan_signature(params))


def attach_compact(state: PlanState, params: dict) -> PlanState:
    """Attach compact weights (``GroupPlan.wc``) to every plan in a state.

    The serving-side half of the OSEL handoff: gather once per params
    version, consume through the fused kernel until the params move. The
    signature is layout-only — it does *not* certify ``wc`` — so holders
    of an attached state must re-attach at every params boundary (the
    refresh hooks below do this automatically) and must never share the
    attached state across params versions (e.g. through the process-wide
    plan cache, which is keyed by layout signature alone).
    """
    if not isinstance(state, PlanState) or not state.plans:
        return state
    return state._replace(plans=grouped.attach_compact(state.plans, params))


def _certify(state: PlanState, params: dict) -> PlanState:
    """The pass-through branch of a refresh: layout certified by ``sig``,
    but any attached ``wc`` snapshots weight *values*, which the
    signature deliberately ignores — re-gather them from the params being
    certified against so online param updates can never serve stale
    weights through a layout-stable plan."""
    if grouped.has_compact(state.plans):
        return attach_compact(state, params)
    return state


def maybe_refresh(params: dict, state: PlanState, it, cfg,
                  schedule=None) -> PlanState:
    """Re-encode ``state`` from the current grouping matrices when due.

    ``it`` may be a traced int32 (``lax.cond`` inside) — the same function
    serves the on-device ``lax.scan`` carry, the mesh path and the host
    loop mirror. ``schedule`` is a ``SparsitySchedule`` (or None: refresh
    every step); its ``refresh`` field picks the policy. Empty states pass
    through untouched. ``state`` must be a :class:`PlanState` — a raw
    plans dict has no signature to compare, so the change-driven modes
    could never fire on one (wrap it via :func:`encode_plans` instead).
    """
    if not isinstance(state, PlanState):
        raise TypeError(
            f"maybe_refresh needs a PlanState, got {type(state).__name__}; "
            "build one with encoder.encode_plans")
    if not state.plans:
        return state
    mode = "period" if schedule is None else \
        getattr(schedule, "refresh", "period")
    if mode not in REFRESH_MODES:
        raise ValueError(f"unknown refresh mode {mode!r}")
    k = 1 if schedule is None else max(1, schedule.refresh_every)
    attached = grouped.has_compact(state.plans)
    fresh = (lambda: attach_compact(encode_plans(params, cfg), params)) \
        if attached else (lambda: encode_plans(params, cfg))
    if mode == "period" and k == 1:
        return fresh()
    due = jnp.asarray(it, jnp.int32) % k == 0
    if mode == "period":
        pred = due
    else:
        changed = plan_signature(params) != state.sig
        pred = changed if mode == "on_change" else changed | due
    return jax.lax.cond(pred, fresh, lambda: _certify(state, params))


def refresh_if_stale(params: dict, state: PlanState, cfg=None, *,
                     encode=None) -> PlanState:
    """Signature-gated re-encode with no step counter — the serving hook.

    :func:`maybe_refresh` assumes a training loop with an iteration
    counter; serving has none. Params are frozen *within* a request but
    may move *between* requests (online tuning), so the request boundary
    — prefill, or a cache reused across requests — must certify the
    cached plans against the *current* params instead of trusting them
    unconditionally. One :func:`plan_signature` pass (~half an encode)
    does that; a bitwise-different layout triggers exactly one re-encode,
    an unchanged layout passes the cached state through untouched.

    ``encode`` overrides the default ``encode_plans(params, cfg)`` for
    stacks with their own encode entry point (the transformer passes its
    ``ModelConfig``-aware encoder). Empty states pass through untouched.
    Traceable: ``lax.cond`` inside, so serve/prefill steps can jit it.
    """
    if not isinstance(state, PlanState):
        raise TypeError(
            f"refresh_if_stale needs a PlanState, got {type(state).__name__};"
            " build one with encoder.encode_plans")
    if not state.plans:
        return state
    if encode is None:
        if cfg is None:
            raise ValueError("refresh_if_stale needs cfg (or encode=)")
        encode = lambda: encode_plans(params, cfg)   # noqa: E731
    if grouped.has_compact(state.plans):
        # Attached compact weights: make the encode branch structurally
        # match, and re-gather wc even on the certified branch — sig is
        # layout-only, it cannot vouch for weight values (online tuning
        # may move W without moving the layout).
        base = encode
        encode = lambda: attach_compact(base(), params)   # noqa: E731
    sig = plan_signature(params)
    # Reuse the signature just computed instead of the one ``encode``
    # re-derives internally (identical by construction — same params):
    # under jit the duplicate inside the branch is then dead code, so a
    # refresh costs one signature + one encode, not two signatures.
    return jax.lax.cond(sig != state.sig,
                        lambda: encode()._replace(sig=sig),
                        lambda: _certify(state, params))


# re-export: the single source of truth for walking FLGW structure
iter_flgw_layers = grouped.iter_flgw_layers
