"""Fully Learnable Weight Grouping (FLGW) — the paper's pruning algorithm.

LearningGroup (Yang et al., 2022) §III-A adopts FLGW (Wang et al., CVPR'19)
as the pruning algorithm for MARL sparse training:

  * a layer ``W ∈ R^{M×N}`` carries two learnable grouping matrices
    ``IG ∈ R^{M×G}`` and ``OG ∈ R^{G×N}``;
  * ``IS = row_onehot_argmax(IG)`` (M×G), ``OS = col_onehot_argmax(OG)`` (G×N);
  * ``Mask = IS @ OS`` (M×N, binary); weights are *masked*, never removed;
  * average sparsity is ``1 - 1/G``; the mask is re-derived every iteration
    as IG/OG train.

OSEL observation 1 (§III-B): ``Mask[i, j] == 1  ⟺  ig_idx[i] == og_idx[j]``
where ``ig_idx = argmax(IG, axis=1)`` and ``og_idx = argmax(OG, axis=0)``.
The mask is therefore fully determined by two small index vectors; this module
builds everything (mask materialization, compact grouped execution, the
straight-through training path) on top of that fact.

Execution paths
---------------
``masked``   paper-faithful algorithm: ``y = x @ (W * Mask)`` — full FLOPs,
             used for accuracy parity and as the numerical oracle.
``grouped``  accelerator dataflow adapted to TPU: permute rows/cols by group
             and run G dense (capM × capN) tiles — FLOPs ÷ G. The Pallas
             kernel lives in ``repro.kernels.flgw_matmul``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FLGWConfig:
    """Static configuration of one FLGW-pruned linear layer."""

    groups: int = 1                 # G; G == 1 ⇒ dense (no pruning)
    path: str = "masked"            # "dense" | "masked" | "grouped"
    ste_temperature: float = 1.0    # softmax temperature of the STE surrogate
    capacity_slack: float = 1.25    # grouped path: per-group row/col capacity slack
    dtype: Any = jnp.float32

    @property
    def enabled(self) -> bool:
        return self.groups > 1 and self.path != "dense"

    @property
    def avg_sparsity(self) -> float:
        return 0.0 if self.groups <= 1 else 1.0 - 1.0 / self.groups


def init_grouping(key: jax.Array, m: int, n: int, groups: int,
                  dtype=jnp.float32) -> dict[str, jax.Array]:
    """Random init of the grouping matrices (paper: 'initialized randomly')."""
    kig, kog = jax.random.split(key)
    return {
        "ig": jax.random.normal(kig, (m, groups), dtype),
        "og": jax.random.normal(kog, (groups, n), dtype),
    }


# ---------------------------------------------------------------------------
# Index extraction and mask construction (OSEL observation 1)
# ---------------------------------------------------------------------------

def grouping_indices(ig: jax.Array, og: jax.Array) -> tuple[jax.Array, jax.Array]:
    """``(ig_idx, og_idx)``: argmax of each IG row / OG column (int32).

    These two vectors are the *entire* sparse metadata of the layer —
    the TPU analogue of the sparse row memory's index lists.
    """
    return (jnp.argmax(ig, axis=1).astype(jnp.int32),
            jnp.argmax(og, axis=0).astype(jnp.int32))


def mask_from_indices(ig_idx: jax.Array, og_idx: jax.Array,
                      dtype=jnp.float32) -> jax.Array:
    """Materialize Mask[i,j] = (ig_idx[i] == og_idx[j]) — O(MN) compares.

    This is OSEL's comparator array, vectorized: no IS @ OS matmul
    (which would be O(M·G·N)).
    """
    return (ig_idx[:, None] == og_idx[None, :]).astype(dtype)


def selection_matrices(ig: jax.Array, og: jax.Array,
                       temperature: float = 1.0) -> tuple[jax.Array, jax.Array]:
    """Straight-through IS/OS: hard one-hot forward, softmax-surrogate backward.

    The paper trains the grouping matrices "based on the errors of the
    corresponding selection matrix"; the STE makes argmax-binarization
    differentiable so IG/OG receive gradients through the mask.
    """
    g = ig.shape[1]
    is_soft = jax.nn.softmax(ig / temperature, axis=1)
    is_hard = jax.nn.one_hot(jnp.argmax(ig, axis=1), g, dtype=ig.dtype)
    is_mat = is_soft + jax.lax.stop_gradient(is_hard - is_soft)

    os_soft = jax.nn.softmax(og / temperature, axis=0)
    os_hard = jax.nn.one_hot(jnp.argmax(og, axis=0), g, dtype=og.dtype,
                             axis=0)
    os_mat = os_soft + jax.lax.stop_gradient(os_hard - os_soft)
    return is_mat, os_mat


def mask_ste(ig: jax.Array, og: jax.Array, temperature: float = 1.0) -> jax.Array:
    """Differentiable mask: forward == mask_from_indices, backward via STE."""
    is_mat, os_mat = selection_matrices(ig, og, temperature)
    return is_mat @ os_mat


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def flgw_linear(x: jax.Array, w: jax.Array, ig: jax.Array, og: jax.Array,
                cfg: FLGWConfig, *, transpose: bool = False,
                plan=None) -> jax.Array:
    """Apply a FLGW-masked linear layer ``y = x @ (W ⊙ Mask)``.

    ``transpose=True`` computes ``y = x @ (W ⊙ Mask)^T`` using the paper's
    weight-transpose trick: Mask^T has the same index structure with IG/OG
    roles swapped, so no transposed metadata is stored.

    ``plan`` is precomputed sparse metadata (``grouped.GroupPlan``) for the
    grouped path — the cached OSEL encoding; ``None`` re-derives it per call.
    """
    if not cfg.enabled:
        return x @ (w.T if transpose else w)
    if cfg.path == "masked":
        mask = mask_ste(ig, og, cfg.ste_temperature).astype(w.dtype)
        wm = w * mask
        return x @ (wm.T if transpose else wm)
    if cfg.path == "grouped":
        # Compact path. Gradient flows to W through the gathered tiles and to
        # IG/OG through a (cheap) STE correction term; see grouped_apply.
        from repro.core.grouped import grouped_apply  # local import: avoids cycle
        return grouped_apply(x, w, ig, og, cfg, transpose=transpose,
                             plan=plan)
    raise ValueError(f"unknown FLGW path {cfg.path!r}")


def mask_sparsity(ig_idx: jax.Array, og_idx: jax.Array,
                  groups: int) -> jax.Array:
    """Actual (not expected) sparsity of the current mask.

    ``nnz = Σ_g rows_g · cols_g`` — the mask is a union of G dense rectangles
    (OSEL observation 2), so sparsity follows from the two group histograms.
    ``groups`` is required: a too-small G silently truncates the bincount
    histograms and overstates sparsity (pass the layer's G, or ``ig.shape[1]``).
    """
    total = ig_idx.shape[0] * og_idx.shape[0]
    rows = jnp.bincount(ig_idx, length=groups)
    cols = jnp.bincount(og_idx, length=groups)
    nnz = jnp.sum(rows * cols)
    return 1.0 - nnz / total
