"""Sparsity / group-number schedules over training.

The paper fixes G per run (G ∈ {1,2,4,8,16,32}) and regenerates the mask
every iteration. For framework use we also expose a refresh-period knob
(mask refresh every k steps — the grouping matrices still train every step,
only the compact re-planning is amortized) and a G warmup schedule.
"""
from __future__ import annotations

import dataclasses


REFRESH_MODES = ("period", "on_change", "hybrid")


@dataclasses.dataclass(frozen=True)
class SparsitySchedule:
    groups: int = 1
    refresh_every: int = 1        # re-derive the mask/plan every k steps
    warmup_steps: int = 0         # run dense for the first k steps
    # Plan-refresh policy (consumed by repro.core.encoder.maybe_refresh):
    #   "period"    — every refresh_every steps (fixed amortization)
    #   "on_change" — only when an ig/og argmax flips (hash-driven; matches
    #                 the paper's churn-early / freeze-late mask dynamics)
    #   "hybrid"    — on change, with refresh_every as a staleness bound
    refresh: str = "period"

    def __post_init__(self):
        if self.refresh not in REFRESH_MODES:
            raise ValueError(
                f"refresh must be one of {REFRESH_MODES}, "
                f"got {self.refresh!r}")

    def groups_at(self, step: int) -> int:
        return 1 if step < self.warmup_steps else self.groups

    def sparse_at(self, step):
        """Is the mask on at ``step``? Works on traced int32 (used inside
        ``lax.scan`` loops, where ``groups_at`` can't branch)."""
        return step >= self.warmup_steps

    def refresh_at(self, step: int) -> bool:
        """Fixed-period refresh predicate (``"period"`` mode only; the
        change-driven modes decide on device from the plan signature)."""
        return step % max(1, self.refresh_every) == 0

    @property
    def avg_sparsity(self) -> float:
        return 0.0 if self.groups <= 1 else 1.0 - 1.0 / self.groups
