"""Balanced group assignment + compact FLGW execution (custom VJP).

This module is the TPU adaptation of LearningGroup's *row-based load
balancing* (§III-C) and the accelerator's compact dataflow.

On the FPGA, rows are dealt evenly to C cores and the 1/G expected workload
makes the allocation converge. TPU SPMD needs *static shapes*, so we go one
step further: a **capacity-balanced assignment** gives every group exactly
``cap = ceil(M/G)`` row slots (and ``ceil(N/G)`` column slots). Rows are
sorted by their argmax group preference (ties broken by preference strength)
and dealt into group buckets in order; overflow rows of a popular group spill
into the next bucket. Deviation from the theoretical balanced workload is 0
by construction — the static-shape analogue of the paper's scheme (measured
against the paper's threshold/row-based schemes in benchmarks/table1).

``grouped_apply`` runs the compact path with a custom VJP:

  * dx, dW   — exact, via the transposed compact product (the paper's
               weight-transpose trick: swap IG/OG roles).
  * dIG, dOG — sparse-restricted straight-through gradient: the mask gradient
               is only known on surviving entries (that is all the backward
               pass computes — same restriction as the FPGA, which updates
               grouping matrices from the sparse errors it has on-chip).
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flgw_matmul import ops as kops
from repro.kernels.plan_encode import ops as pe_ops
from repro.sharding.partition import constrain


class GroupPlan(NamedTuple):
    """Static-shape compact layout of one FLGW layer's mask.

    ``wc`` is the optional weight half of the encode output — the dense W
    compacted to ``(G, capM, capN)`` (:func:`attach_compact`), the paper's
    OSEL→core handoff. Plans used for *training* leave it ``None`` (W
    moves every step); serving attaches it once per params version so the
    consume path stops re-gathering W per call. Because ``wc`` caches
    *weight values* — unlike the int layout, which a plan signature
    certifies — it must always be (re-)derived from the params actually
    being served: it never rides the process-wide plan cache, and the
    certify path re-attaches it even when the layout signature matches.
    """
    row_ids: jax.Array    # (G, capM) int32 — rows assigned to each group
    col_ids: jax.Array    # (G, capN) int32
    row_valid: jax.Array  # (G, capM) bool — padding slots are False
    col_valid: jax.Array  # (G, capN) bool
    row_group: jax.Array  # (M,) int32 — balanced group of each row
    col_group: jax.Array  # (N,) int32
    wc: Optional[jax.Array] = None  # (G, capM, capN) compact weights


def balanced_assign(scores: jax.Array, axis: int,
                    slack: float = 1.0) -> jax.Array:
    """Deal items into equal-capacity groups by argmax preference.

    ``scores``: (..., M, G) if axis==1 (rows of IG) or (..., G, N) if
    axis==0 (columns of OG); leading dims batch over stacked layers.
    Returns (..., G, cap) int32 item indices with
    ``cap = ceil(M/G · slack)``.

    Items keep their argmax group as long as it has a free slot (the
    ``slack`` headroom makes that the common case — exactly the MoE
    capacity-factor trade); only true overflow items — the *least*
    confident ones of an over-popular group — spill into other groups'
    free slots. ``slack == 1.0`` reproduces the strict equal-deal.

    Runs on the ``plan_encode`` Pallas kernel (comparator-rank counting
    sort; the lexsort reference is preserved in
    ``repro.kernels.plan_encode.ref`` and used under reference-impl mode).
    """
    return pe_ops.balanced_assign(scores, axis, slack)


def _group_of_item(ids: jax.Array, size: int) -> jax.Array:
    """(..., G, cap) item ids -> (..., size) group of each item (inverse
    lookup via scatter; padded slots were clipped into range upstream)."""
    lead = ids.shape[:-2]
    g = ids.shape[-2]
    gid = jnp.broadcast_to(
        jnp.arange(g, dtype=jnp.int32)[:, None], ids.shape[-2:]).reshape(-1)
    if not lead:
        return (jnp.zeros((size,), jnp.int32)
                .at[ids.reshape(-1)].set(gid, mode="drop"))
    length = int(np.prod(lead))
    flat = ids.reshape(length, -1)
    out = (jnp.zeros((length, size), jnp.int32)
           .at[jnp.arange(length)[:, None], flat]
           .set(jnp.broadcast_to(gid[None], flat.shape), mode="drop"))
    return out.reshape(*lead, size)


def make_plan(ig: jax.Array, og: jax.Array,
              slack: float = 1.0) -> GroupPlan:
    """Build the compact layout from the grouping matrices.

    ``ig``: (..., M, G), ``og``: (..., G, N) — leading dims (the stacked
    scan-layer axis of the LM decoder) batch through the plan-encode
    kernel's grid in one launch; every GroupPlan leaf comes back with the
    same leading dims.
    """
    m = ig.shape[-2]
    n = og.shape[-1]
    row_ids = balanced_assign(ig, axis=1, slack=slack)   # (..., G, capM)
    col_ids = balanced_assign(og, axis=0, slack=slack)   # (..., G, capN)
    row_valid = row_ids < m
    col_valid = col_ids < n
    row_ids = jnp.minimum(row_ids, m - 1)
    col_ids = jnp.minimum(col_ids, n - 1)
    return GroupPlan(row_ids, col_ids, row_valid, col_valid,
                     _group_of_item(row_ids, m), _group_of_item(col_ids, n))


def transpose_plan(plan: GroupPlan) -> GroupPlan:
    """Plan of Mask^T — the weight-transpose trick on cached metadata.

    ``make_plan(og.T, ig.T)`` is exactly the row/col swap of
    ``make_plan(ig, og)`` (``balanced_assign(og, axis=0) ==
    balanced_assign(og.T, axis=1)``), so the transposed layout is free:
    no re-encoding, matching the paper's transposed-encode reuse (§III-B).
    """
    wc = None if plan.wc is None else jnp.swapaxes(plan.wc, -1, -2)
    return GroupPlan(row_ids=plan.col_ids, col_ids=plan.row_ids,
                     row_valid=plan.col_valid, col_valid=plan.row_valid,
                     row_group=plan.col_group, col_group=plan.row_group,
                     wc=wc)


# ---------------------------------------------------------------------------
# RawPlans: one GroupPlan per FLGW layer of a param tree (OSEL analogue)
# ---------------------------------------------------------------------------

# RawPlans mirrors a params pytree: nested dict whose leaves are the
# GroupPlan of every projection dict carrying ig/og grouping matrices.
# (repro.core.encoder.PlanState wraps this dict with the argmax signature
# used for change-driven refresh — that is the type most callers handle.)
RawPlans = dict[str, Any]


def iter_flgw_layers(params: dict, _path=()):
    """Yield ``(path, layer_dict)`` for every FLGW-carrying projection —
    any nested dict holding ``ig``/``og`` grouping matrices. The single
    source of truth for walking a param tree's FLGW structure.

    Iterates in sorted key order — the same canonical order jit's pytree
    flattening gives dicts — so order-sensitive consumers (the plan
    signature's per-layer salts) agree between eager and traced calls."""
    for name, p in sorted(params.items()):
        if not isinstance(p, dict):
            continue
        if "ig" in p:
            yield (*_path, name), p
        else:
            yield from iter_flgw_layers(p, (*_path, name))


def encode_plans(params: dict, cfg) -> RawPlans:
    """One encoding pass over a param tree — the OSEL loop's TPU analogue.

    The paper encodes the FLGW mask *once per iteration* into compact
    sparse metadata that the whole forward/backward then reuses (§III-B).
    Here that metadata is the capacity-balanced :class:`GroupPlan`; this
    builds one per FLGW-carrying projection so callers can cache and
    re-encode it on their own schedule instead of re-deriving it inside
    every projection. The dict mirrors the params nesting; stacked
    (scanned) layers encode in one batched kernel launch and get plans
    stacked along the same leading axes.

    This returns the raw plans dict; most callers want
    :func:`repro.core.encoder.encode_plans`, which pairs it with the
    argmax signature used for change-driven refresh.
    """
    plans: RawPlans = {}
    for path, p in iter_flgw_layers(params):
        node = plans
        for name in path[:-1]:
            node = node.setdefault(name, {})
        node[path[-1]] = make_plan(p["ig"], p["og"], cfg.capacity_slack)
    return plans


def _map_plans(plans: RawPlans, params: dict, fn) -> RawPlans:
    """Rebuild ``plans`` with ``fn(plan, layer_params)`` at every FLGW
    projection, walking params and plans in lockstep."""
    out: RawPlans = {}
    for path, p in iter_flgw_layers(params):
        node_in, node_out = plans, out
        for name in path[:-1]:
            node_in = node_in[name]
            node_out = node_out.setdefault(name, {})
        node_out[path[-1]] = fn(node_in[path[-1]], p)
    return out


def attach_compact(plans: RawPlans, params: dict) -> RawPlans:
    """Attach the compact weights ``W_c`` to every plan — the weight half
    of the paper's OSEL encode output (§III-B: the encoder emits the
    sparse *data*, not just indices, and the cores consume it directly).

    One XLA gather per projection, amortized over every consume until the
    params move; :func:`grouped_apply` then takes the fused kernel path
    (``flgw_matmul.grouped_matmul_fused``), which reads ``wc`` as-is and
    gathers only the activations — in its prologue. ``wc`` snapshots
    weight *values*: re-attach whenever params change (the plan signature
    does **not** cover it — see :class:`GroupPlan`). Stacked/scanned and
    vmapped-expert layers attach along their leading dims unchanged.
    """
    def _one(plan: GroupPlan, p: dict) -> GroupPlan:
        wc = kops.compact_weights(p["w"], plan.row_ids, plan.col_ids,
                                  plan.row_valid, plan.col_valid)
        return plan._replace(wc=wc)
    return _map_plans(plans, params, _one)


def strip_compact(plans: RawPlans) -> RawPlans:
    """Drop every plan's ``wc`` — back to the pure-layout (int/bool) tree
    that training carries and the process-wide plan cache may hold."""
    return jax.tree.map(
        lambda p: p._replace(wc=None) if isinstance(p, GroupPlan) else p,
        plans, is_leaf=lambda p: isinstance(p, GroupPlan))


def has_compact(plans) -> bool:
    """Whether any plan in the tree carries attached compact weights."""
    found = False
    def _look(p):
        nonlocal found
        if isinstance(p, GroupPlan) and p.wc is not None:
            found = True
        return p
    jax.tree.map(_look, plans, is_leaf=lambda p: isinstance(p, GroupPlan))
    return found


# ---------------------------------------------------------------------------
# Compact apply with custom VJP
# ---------------------------------------------------------------------------

def _gather_x(x, plan: GroupPlan):
    b = x.shape[0]
    g, cap_m = plan.row_ids.shape
    xg = jnp.take(x, plan.row_ids.reshape(-1), axis=1)
    xg = xg.reshape(b, g, cap_m).transpose(1, 0, 2)
    return jnp.where(plan.row_valid[:, None, :], xg, 0)


def _gather_w(w, plan: GroupPlan):
    wc = w[plan.row_ids[:, :, None], plan.col_ids[:, None, :]]
    return jnp.where(plan.row_valid[:, :, None] & plan.col_valid[:, None, :],
                     wc, 0)


def _core_matmul(x, w, plan: GroupPlan, interpret, impl):
    """One compact product. Plans carrying attached compact weights take
    the fused OSEL→core path (in-kernel activation gather, zero per-call
    W traffic); bare plans take the per-call XLA-gather path; the jnp
    reference stays the GSPMD-shardable fallback. The three agree —
    fused vs gather bitwise (same tiles, same accumulation order)."""
    if plan.wc is not None and impl != "reference":
        return kops.grouped_matmul_fused(x, plan.wc, plan.row_ids,
                                         plan.row_valid, plan.col_ids,
                                         plan.col_valid, n=w.shape[1],
                                         interpret=interpret)
    return kops.grouped_matmul(x, w, plan.row_ids, plan.col_ids,
                               plan.row_valid, plan.col_valid,
                               interpret=interpret, impl=impl)


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _grouped_core(x, w, ig, og, plan: GroupPlan, temperature: float,
                  interpret: bool, impl: str):
    """Compact matmul against *precomputed* sparse metadata.

    The plan is a VJP input (not rebuilt in fwd/bwd): the backward pass
    reuses the very same metadata via the transpose trick, so one encode
    serves the whole step — the paper's OSEL amortization.
    """
    return _core_matmul(x, w, plan, interpret, impl)


def _grouped_fwd(x, w, ig, og, plan, temperature, interpret, impl):
    y = _core_matmul(x, w, plan, interpret, impl)
    return y, (x, w, ig, og, plan)


def _grouped_bwd(temperature, interpret, impl, res, gy):
    x, w, ig, og, plan = res
    b = x.shape[0]
    m, g = ig.shape
    n = og.shape[1]
    cap_m = plan.row_ids.shape[1]
    cap_n = plan.col_ids.shape[1]

    xg = constrain(_gather_x(x, plan), (None, "batch", None))
    wc = plan.wc if plan.wc is not None else _gather_w(w, plan)
    wc = constrain(wc, (None, None, "flgw_cap"))
    gc = jnp.take(gy, plan.col_ids.reshape(-1), axis=1)  # (B, G*capN)
    gc = gc.reshape(b, g, cap_n).transpose(1, 0, 2)      # (G, B, capN)
    gc = jnp.where(plan.col_valid[:, None, :], gc, 0)
    gc = constrain(gc, (None, "batch", "flgw_cap"))

    # dX: transposed compact product — the paper's weight-transpose trick:
    # Mask^T has the same structure with IG/OG swapped, so we reuse the
    # compact tiles with the contraction flipped.
    dxc = jnp.einsum("gbn,gmn->gbm", gc, wc,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    flat_rows = jnp.where(plan.row_valid, plan.row_ids, m).reshape(-1)
    dx = (jnp.zeros((b, m), x.dtype)
          .at[:, flat_rows]
          .set(dxc.transpose(1, 0, 2).reshape(b, -1), mode="drop"))

    # dW: compact outer products scattered to the dense weight.
    dwc = jnp.einsum("gbm,gbn->gmn", xg, gc,
                     preferred_element_type=jnp.float32).astype(w.dtype)
    dw = (jnp.zeros((m, n), w.dtype)
          .at[plan.row_ids[:, :, None], plan.col_ids[:, None, :]]
          .add(dwc, mode="drop"))

    # dIG/dOG: sparse-restricted STE. The mask gradient on surviving entries
    # is dMask = dW ⊙ W; reduce it to per-row / per-column scalars and push
    # through the softmax Jacobian at the assigned group.
    s_rows_c = jnp.sum(dwc * wc, axis=2)                 # (G, capM)
    s_row = (jnp.zeros((m,), jnp.float32)
             .at[flat_rows.reshape(g, cap_m)]
             .add(s_rows_c.astype(jnp.float32), mode="drop"))
    s_cols_c = jnp.sum(dwc * wc, axis=1)                 # (G, capN)
    flat_cols = jnp.where(plan.col_valid, plan.col_ids, n).reshape(-1)
    s_col = (jnp.zeros((n,), jnp.float32)
             .at[flat_cols.reshape(g, cap_n)]
             .add(s_cols_c.astype(jnp.float32), mode="drop"))

    tau = temperature
    soft_ig = jax.nn.softmax(ig / tau, axis=1)           # (M, G)
    pg_row = jax.nn.one_hot(plan.row_group, g, dtype=soft_ig.dtype)
    sel_r = jnp.sum(soft_ig * pg_row, axis=1, keepdims=True)
    dig = (s_row[:, None] / tau) * sel_r * (pg_row - soft_ig)
    soft_og = jax.nn.softmax(og / tau, axis=0)           # (G, N)
    pg_col = jax.nn.one_hot(plan.col_group, g, dtype=soft_og.dtype, axis=0)
    sel_c = jnp.sum(soft_og * pg_col, axis=0, keepdims=True)
    dog = (s_col[None, :] / tau) * sel_c * (pg_col - soft_og)

    # Plan entries are metadata: int/bool leaves get float0 cotangents; an
    # attached ``wc`` (a float snapshot derived from w) gets symbolic
    # zeros — the full weight gradient already flows through ``dw``.
    dplan = jax.tree.map(
        lambda a: (jnp.zeros(a.shape, a.dtype)
                   if jnp.issubdtype(a.dtype, jnp.inexact)
                   else np.zeros(a.shape, jax.dtypes.float0)), plan)
    return dx, dw, dig.astype(ig.dtype), dog.astype(og.dtype), dplan


_grouped_core.defvjp(_grouped_fwd, _grouped_bwd)


def grouped_apply(x: jax.Array, w: jax.Array, ig: jax.Array, og: jax.Array,
                  cfg, *, transpose: bool = False,
                  plan: Optional[GroupPlan] = None) -> jax.Array:
    """Compact FLGW linear. ``x``: (..., M) (or (..., N) when transposed).

    ``plan`` is the cached sparse metadata of the *untransposed* layer
    (see :func:`encode_plans`); when omitted the plan is re-derived here —
    the unamortized fallback, one encode per projection call.
    """
    interpret = kops.default_interpret()
    impl = "reference" if kops._REF_MODE else "pallas"
    if transpose:
        # y = x @ (W ⊙ M)^T == grouped(x, W^T) with IG/OG roles swapped.
        w_t, ig_t, og_t = w.T, og.T, ig.T
        plan = transpose_plan(plan) if plan is not None else None
    else:
        w_t, ig_t, og_t = w, ig, og
    if plan is None:
        plan = make_plan(ig_t, og_t, cfg.capacity_slack)
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    y = _grouped_core(xf, w_t, ig_t, og_t, plan, cfg.ste_temperature,
                      interpret, impl)
    return y.reshape(*lead, -1)
