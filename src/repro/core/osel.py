"""OSEL — On-chip Sparse data Encoding Loop (paper §III-B).

Three artifacts live here:

1. ``encode``: the functional TPU equivalent of the OSEL encoder. Given the
   two grouping-index vectors it produces the *sparse row memory* content —
   per-group bitvectors (≤ G of them, observation 2), per-row workloads and
   compact non-zero column indices. Metadata is O(G·N + M) bits, never M×N.

2. ``transpose_encode``: the backward-pass encoder — identical loop with the
   IG/OG roles swapped (the paper's weight-transpose support).

3. ``cycle_model`` / ``footprint_model``: a faithful cycle/byte-accurate
   model of the FPGA encoder (hit/miss loop of Fig. 5) and of the paper's
   baseline (recompute the bitvector for every row). These reproduce the
   Fig. 10 efficiency claims (up to 5.72× cycles, 6.81× memory) analytically
   — those numbers are properties of the encoding loop, not of FLOP
   throughput, so a model is the honest way to validate them off-FPGA.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SparseRowMemory(NamedTuple):
    """Content of the sparse row memory (one tuple per *group*, obs. 2)."""
    bitvectors: jax.Array   # (G, N) bool — row pattern of each group
    nz_indices: jax.Array   # (G, capN) int32 — compact column ids (padded N)
    workloads: jax.Array    # (G,) int32 — nnz per pattern
    index_list: jax.Array   # (M,) int32 — per-row reference into the cache


def encode(ig_idx: jax.Array, og_idx: jax.Array, groups: int,
           cap_n: int | None = None) -> SparseRowMemory:
    """Vectorized OSEL encode: all ≤G patterns in one pass.

    The FPGA walks rows serially with a hit/miss cache; a serial automaton
    would waste the VPU, so we compute every group's bitvector at once —
    same output, same asymptotic metadata size.
    """
    n = og_idx.shape[0]
    if cap_n is None:
        cap_n = n
    gid = jnp.arange(groups, dtype=jnp.int32)
    bitvectors = gid[:, None] == og_idx[None, :]              # (G, N)
    workloads = jnp.sum(bitvectors, axis=1).astype(jnp.int32)
    # Compact column indices: stable sort puts in-group columns first.
    order = jnp.argsort(~bitvectors, axis=1, stable=True)     # (G, N)
    valid = jnp.arange(n)[None, :] < workloads[:, None]
    nz = jnp.where(valid, order, n).astype(jnp.int32)[:, :cap_n]
    return SparseRowMemory(bitvectors, nz, workloads,
                           ig_idx.astype(jnp.int32))


def transpose_encode(ig_idx: jax.Array, og_idx: jax.Array,
                     groups: int) -> SparseRowMemory:
    """Backward-pass encoder: rows of Mask^T are indexed by og_idx and the
    patterns are drawn from ig_idx — the same loop with roles swapped."""
    return encode(og_idx, ig_idx, groups)


def mask_from_memory(mem: SparseRowMemory) -> jax.Array:
    """Reconstruct the full mask from the sparse row memory (for checks)."""
    return mem.bitvectors[mem.index_list]


# ---------------------------------------------------------------------------
# FPGA cycle / footprint models (Fig. 10 reproduction)
# ---------------------------------------------------------------------------

def cycle_model(m: int, n: int, g: int, *, use_osel: bool = True,
                compare_width: int = 16, base_max_lanes: int = 3,
                weight_width: int = 32) -> dict[str, float]:
    """Cycle count of on-chip sparse data generation + weight compression.

    Calibrated model of the paper's Fig. 10 setup (constants documented,
    chosen to match the published curve shape and anchors):

    * Baseline (no OSEL): the max-index scan over the grouping matrices is
      *serial* in G (``base_max_lanes`` elements/cycle — the paper notes the
      baseline "takes more time to find the max index ... as a large G makes
      large group matrices"), then the bitvector is recomputed for every row
      (``compare_width`` parallel comparators) and every tuple stored.
    * OSEL: the comparator array checks the IG max index against all OG max
      indexes in parallel (⌈G/compare_width⌉ cycles per scan element), the
      bitvector is computed only on a cache miss (≤ G misses), a hit costs
      one index-list append.
    * Weight compression streams the m·n/G unmasked weights at
      ``weight_width`` words/cycle and is common to both.

    With the defaults this reproduces the paper's trend (baseline ↑ with G,
    OSEL ↓ until G=32) and a peak speedup of 5.6× vs the published 5.72×.
    """
    compression = (m * n) // g // weight_width
    if use_osel:
        max_index = (m + n) * -(-g // compare_width)
        miss = min(g, m) * max(1, n // compare_width)
        hit = m - min(g, m)
        return {"MaxIndex": max_index, "IndexMiss": miss, "Hit": hit,
                "WeightCompression": compression,
                "total": max_index + miss + hit + compression}
    max_index = (m + n) * g / base_max_lanes    # serial max-index scan
    bitgen = m * max(1, n // compare_width)     # recompute every row
    store = m                                   # store every tuple
    return {"MaxIndex": max_index, "BitvectorGen": bitgen, "Store": store,
            "WeightCompression": compression,
            "total": max_index + bitgen + store + compression}


def footprint_model(m: int, n: int, g: int, *, bytes_per_weight: int = 2,
                    bytes_per_grouping: int = 1,
                    use_grouping: bool = True) -> dict[str, float]:
    """On-chip memory footprint (bytes) of the parameters in actual use.

    Dense: the full m·n weight matrix. Grouped: unmasked weights (m·n/g) +
    grouping matrices (m·g + g·n, stored 8-bit — back-solving the paper's
    published 1.95× compression at G=2 pins the grouping storage at one
    byte/entry) + the sparse row memory, which holds ≤ G tuples of
    (bitvector: n bits, workload: ⌈log2 n⌉ bits, max index: ⌈log2 g⌉ bits)
    plus the m-entry index list (⌈log2 g⌉ bits each).
    """
    if not use_grouping or g <= 1:
        return {"weights": m * n * bytes_per_weight, "grouping": 0,
                "sparse_row_memory": 0,
                "total": m * n * bytes_per_weight}
    weights = (m * n // g) * bytes_per_weight
    grouping = (m * g + g * n) * bytes_per_grouping
    bits_wl = int(np.ceil(np.log2(max(n, 2))))
    bits_g = max(1, int(np.ceil(np.log2(max(g, 2)))))
    srm_bits = g * (n + bits_wl + bits_g) + m * bits_g
    srm = srm_bits / 8.0
    return {"weights": weights, "grouping": grouping,
            "sparse_row_memory": srm,
            "total": weights + grouping + srm}
