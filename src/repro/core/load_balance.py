"""Workload-allocation schemes (paper §III-C, Table I).

Three allocators distribute the rows of a masked weight matrix to C cores
(on TPU: C = model-axis shards):

* ``threshold_allocate`` — the paper's *baseline*: walk rows in order,
  filling a core until its assigned non-zero count exceeds
  ``total_nnz / C``, then move to the next core. Suffers from unaligned
  last-core assignments (the paper's explanation for Table I).

* ``row_allocate`` — the paper's scheme: deal an equal number of *rows* to
  every core; E[nnz per row] = N/G makes the per-core workload converge.

* ``balanced_allocate`` — our TPU adaptation: the capacity-balanced group
  assignment of ``repro.core.grouped`` also equalizes per-core row counts
  *within each group*, so deviation is ~0 by construction.

All of them return per-core workloads so the Table I deviation metric
(max |core_nnz − total_nnz/C|) can be compared.
"""
from __future__ import annotations

import numpy as np


def row_workloads(mask: np.ndarray) -> np.ndarray:
    return np.asarray(mask).sum(axis=1)


def threshold_allocate(mask: np.ndarray, cores: int) -> np.ndarray:
    """Paper's baseline. Returns nnz per core (len == cores)."""
    wl = row_workloads(mask)
    threshold = wl.sum() / cores
    per_core = np.zeros(cores, dtype=np.int64)
    core = 0
    for w in wl:
        if per_core[core] >= threshold and core < cores - 1:
            core += 1
        per_core[core] += int(w)
    return per_core


def row_allocate(mask: np.ndarray, cores: int) -> np.ndarray:
    """Paper's row-based scheme: equal row counts per core (round-robin
    blocks, as the load-allocation unit deals rows in order)."""
    wl = row_workloads(mask)
    per_core = np.zeros(cores, dtype=np.int64)
    splits = np.array_split(np.arange(len(wl)), cores)
    for c, rows in enumerate(splits):
        per_core[c] = int(wl[rows].sum())
    return per_core


def balanced_allocate(row_group: np.ndarray, col_group: np.ndarray,
                      cores: int, groups: int) -> np.ndarray:
    """TPU adaptation: rows dealt round-robin per group bucket, so every
    core receives ``capM/C`` rows of *each* group. The remainder row of
    each group rotates across cores (group g's spare goes to core g mod C),
    so remainders cancel instead of piling onto core 0."""
    cols_per_group = np.bincount(col_group, minlength=groups)
    per_core = np.zeros(cores, dtype=np.int64)
    for g in range(groups):
        rows_g = np.where(row_group == g)[0]
        splits = np.array_split(rows_g, cores)
        for c, rows in enumerate(splits):
            per_core[(c + g) % cores] += len(rows) * int(cols_per_group[g])
    return per_core


def deviation(per_core: np.ndarray) -> float:
    """Table I metric: max deviation from the theoretical balanced load."""
    ideal = per_core.sum() / len(per_core)
    return float(np.max(np.abs(per_core - ideal)))
