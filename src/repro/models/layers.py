"""Layer primitives shared by all architectures.

Every projection goes through ``proj`` which dispatches on the presence of
FLGW grouping parameters — the paper's pruning technique is a first-class
feature of every linear layer in the framework, not a bolt-on.

Parameters are plain pytrees (nested dicts); initializers return
``(params, specs)`` where ``specs`` mirrors the tree with logical sharding
axis names consumed by ``repro.sharding.partition``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.encoder import PlanState as EncoderPlanState
from repro.core.flgw import FLGWConfig, init_grouping, mask_ste
from repro.core.grouped import GroupPlan, grouped_apply
from repro.sharding.partition import constrain


# ---------------------------------------------------------------------------
# Dense / FLGW projection
# ---------------------------------------------------------------------------

def dense_init(key, m: int, n: int, *, flgw: Optional[FLGWConfig] = None,
               axes=("in", "out"), dtype=jnp.bfloat16, scale: float = 1.0):
    """One projection W: (m, n), optionally carrying FLGW grouping params."""
    kw, kg = jax.random.split(key)
    std = scale / (m ** 0.5)
    params = {"w": (jax.random.normal(kw, (m, n), jnp.float32) * std
                    ).astype(dtype)}
    specs = {"w": axes}
    if flgw is not None and flgw.groups > 1:
        g = init_grouping(kg, m, n, flgw.groups, jnp.float32)
        params["ig"] = g["ig"]
        params["og"] = g["og"]
        specs["ig"] = (axes[0], None)
        specs["og"] = (None, axes[1])
    return params, specs


def proj(p: dict, x: jax.Array, flgw: Optional[FLGWConfig] = None,
         *, transpose: bool = False,
         plan: Optional[GroupPlan] = None) -> jax.Array:
    """y = x @ W (or x @ W^T), FLGW-masked when grouping params exist.

    ``plan`` is this layer's cached sparse metadata for the grouped path
    (one entry of an ``encode_plans`` PlanState); ``None`` falls back to
    re-encoding inside the projection — correct but unamortized.
    """
    w = p["w"]
    if flgw is None or not flgw.enabled or "ig" not in p:
        return x @ (w.T if transpose else w)
    if flgw.path == "grouped":
        return grouped_apply(x, w, p["ig"], p["og"], flgw,
                             transpose=transpose, plan=plan)
    mask = mask_ste(p["ig"], p["og"], flgw.ste_temperature).astype(w.dtype)
    wm = w * mask
    return x @ (wm.T if transpose else wm)


# ---------------------------------------------------------------------------
# Norms / activations / embeddings
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}, {"scale": (None,)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + p["scale"])).astype(dt)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    e = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return {"embedding": e.astype(dtype)}, {"embedding": ("vocab", "embed")}


def embed(p: dict, tokens: jax.Array, d_model: int) -> jax.Array:
    # Gemma-style sqrt(d) scaling keeps the residual stream O(1).
    return p["embedding"][tokens] * jnp.asarray(
        d_model ** 0.5, p["embedding"].dtype)


def unembed(p: dict, x: jax.Array) -> jax.Array:
    return x @ p["embedding"].T


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return jnp.tanh(x / cap) * cap if cap > 0 else x


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (FLGW-capable)
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, ff: int, *, gated: bool = True,
             flgw: Optional[FLGWConfig] = None, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    params, specs = {}, {}
    params["up"], specs["up"] = dense_init(
        ks[0], d, ff, flgw=flgw, axes=("embed", "ffn"), dtype=dtype)
    if gated:
        params["gate"], specs["gate"] = dense_init(
            ks[1], d, ff, flgw=flgw, axes=("embed", "ffn"), dtype=dtype)
    params["down"], specs["down"] = dense_init(
        ks[2], ff, d, flgw=flgw, axes=("ffn", "embed"), dtype=dtype)
    return params, specs


def plan_of(plans, name: str) -> Optional[GroupPlan]:
    """Look one entry out of a PlanState / nested plans dict (None when
    absent). Accepts the ``repro.core.encoder.PlanState`` wrapper, the raw
    nested dict, or None; the result is a GroupPlan at leaf level or a
    sub-dict for nested lookups."""
    if isinstance(plans, EncoderPlanState):
        plans = plans.plans
    if not plans:
        return None
    return plans.get(name)


def mlp(p: dict, x: jax.Array, flgw: Optional[FLGWConfig] = None,
        plans: Optional[dict] = None) -> jax.Array:
    up = proj(p["up"], x, flgw, plan=plan_of(plans, "up"))
    if "gate" in p:
        up = jax.nn.gelu(proj(p["gate"], x, flgw,
                              plan=plan_of(plans, "gate"))) * up
    else:
        up = jax.nn.gelu(up)
    up = constrain(up, ("batch", None, "ffn"))   # TP on the hidden dim
    return proj(p["down"], up, flgw, plan=plan_of(plans, "down"))
