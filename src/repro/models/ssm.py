"""Mamba2 (SSD — state-space duality) mixer layer.

Training/prefill uses the chunked SSD algorithm: within a chunk the
quadratic "attention-like" form runs on the MXU; across chunks a
``lax.scan`` carries the (B, H, P, N) state — sub-quadratic in sequence
length and the reason mamba2/jamba run the ``long_500k`` cell. Decode is a
single O(1) state update per token.

Layout: heads H = d_inner / head_dim (P = head_dim), state width N, one
B/C group shared across heads (n_groups = 1, as mamba2-1.3b).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.flgw import FLGWConfig
from repro.models.layers import dense_init, plan_of, proj, rmsnorm


def ssm_init(key, cfg, *, flgw: Optional[FLGWConfig] = None):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 6)
    params, specs = {}, {}
    # in_proj -> [z (di), xBC (di + 2N), dt (H)]
    params["in"], specs["in"] = dense_init(
        ks[0], d, 2 * di + 2 * n + h, flgw=flgw, axes=("embed", "ffn"),
        dtype=cfg.dtype)
    params["out"], specs["out"] = dense_init(
        ks[1], di, d, flgw=flgw, axes=("ffn", "embed"), dtype=cfg.dtype)
    params["conv_w"] = (jax.random.normal(ks[2], (cfg.conv_width, conv_ch),
                                          jnp.float32) * 0.2).astype(cfg.dtype)
    specs["conv_w"] = (None, "ffn")
    params["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32))
    specs["A_log"] = ("heads",)
    params["D"] = jnp.ones((h,), jnp.float32)
    specs["D"] = ("heads",)
    params["dt_bias"] = jnp.zeros((h,), jnp.float32)
    specs["dt_bias"] = ("heads",)
    params["norm"] = {"scale": jnp.zeros((di,), jnp.float32)}
    specs["norm"] = {"scale": (None,)}
    return params, specs


def _causal_conv(x, w):
    """Depthwise causal conv, x: (B, S, C), w: (W, C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out


def _ssd_chunked(xh, bm, cm, dt, a_neg, chunk: int, unroll: bool = False):
    """Chunked SSD scan.

    xh: (B, S, H, P); bm/cm: (B, S, N); dt: (B, S, H); a_neg: (H,) negative.
    Returns y: (B, S, H, P). ``unroll=True`` replaces the cross-chunk
    ``lax.scan`` with a Python loop (identical math) — used by the dry-run
    cost variant, since HLO cost analysis counts a while-loop body once.
    """
    b, s, h, p = xh.shape
    n = bm.shape[-1]
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)

    def to_chunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).transpose(
            1, 0, *range(2, t.ndim + 1))

    xc, bc, cc, dtc = map(to_chunks, (xh, bm, cm, dt))  # leading nc

    def body(hstate, inp):
        x_i, b_i, c_i, dt_i = inp           # (B,L,H,P) (B,L,N) (B,L,N) (B,L,H)
        a_i = dt_i * a_neg                  # (B,L,H)
        cs = jnp.cumsum(a_i, axis=1)        # inclusive
        # off-diagonal: contribution of the incoming state
        y_off = jnp.einsum("bln,bhpn->blhp", c_i, hstate) * \
            jnp.exp(cs)[..., None]
        # within-chunk quadratic form
        cb = jnp.einsum("bln,bmn->blm", c_i, b_i)          # (B,L,L)
        seg = cs[:, :, None, :] - cs[:, None, :, :]        # (B,L,L,H)
        li = jnp.arange(chunk)
        causal = (li[:, None] >= li[None, :])[None, :, :, None]
        decay = jnp.where(causal, jnp.exp(seg), 0.0)
        y_diag = jnp.einsum("blm,blmh,bmh,bmhp->blhp",
                            cb, decay, dt_i, x_i.astype(jnp.float32))
        # state update: h' = exp(sum a) h + sum_t exp(cs_end - cs_t) dt B x
        dec_state = jnp.exp(cs[:, -1:, :] - cs)            # (B,L,H)
        dbx = jnp.einsum("bln,blh,blhp->bhpn",
                         b_i, dt_i * dec_state, x_i.astype(jnp.float32))
        hstate = hstate * jnp.exp(cs[:, -1])[..., None, None] + dbx
        return hstate, (y_off + y_diag).astype(xh.dtype)

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    if unroll:
        hstate, ys = h0, []
        for i in range(nc):
            hstate, y_i = body(hstate, (xc[i], bc[i], cc[i], dtc[i]))
            ys.append(y_i)
        yc = jnp.stack(ys)
    else:
        _, yc = jax.lax.scan(body, h0, (xc, bc, cc, dtc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y


def ssm_step(hstate, x_t, b_t, c_t, dt_t, a_neg):
    """One decode step. hstate: (B,H,P,N); x_t: (B,H,P); b_t/c_t: (B,N);
    dt_t: (B,H). Returns (new_state, y_t)."""
    decay = jnp.exp(dt_t * a_neg)                           # (B,H)
    dbx = jnp.einsum("bn,bh,bhp->bhpn", b_t, dt_t, x_t.astype(jnp.float32))
    hstate = hstate * decay[..., None, None] + dbx
    y = jnp.einsum("bn,bhpn->bhp", c_t, hstate)
    return hstate, y.astype(x_t.dtype)


def ssm(p, x, cfg, *, cache: Optional[dict] = None, chunk: int = 256,
        flgw: Optional[FLGWConfig] = None, unroll: bool = False,
        plans=None):
    """Mamba2 block. x: (B, S, d). Returns (out, new_cache).

    ``plans``: this layer's entry of a cached PlanState — GroupPlans for
    the ``in``/``out`` projections on the FLGW grouped path (None falls
    back to per-call re-encoding inside ``proj``).
    """
    b, s, d = x.shape
    di, n, h, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = proj(p["in"], x, flgw, plan=plan_of(plans, "in"))
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a_neg = -jnp.exp(p["A_log"])                                 # (H,)

    if cache is None:
        xbc = _causal_conv(xbc, p["conv_w"])
        xbc = jax.nn.silu(xbc)
        xh, bm, cm = jnp.split(xbc, [di, di + n], axis=-1)
        xh = xh.reshape(b, s, h, hd)
        chunk = min(chunk, s)
        y = _ssd_chunked(xh, bm.astype(jnp.float32), cm.astype(jnp.float32),
                         dt, a_neg, chunk, unroll=unroll)
        new_cache = None
    else:
        # Decode: conv ring buffer + O(1) state update (s == 1).
        conv_state = cache["conv"]                       # (B, W-1, conv_ch)
        window = jnp.concatenate([conv_state, xbc], axis=1)
        xbc_t = jnp.einsum("bwc,wc->bc", window, p["conv_w"])[:, None, :]
        xbc_t = jax.nn.silu(xbc_t)
        xh, bm, cm = jnp.split(xbc_t, [di, di + n], axis=-1)
        xh = xh.reshape(b, h, hd)
        hstate, y = ssm_step(cache["state"], xh,
                             bm[:, 0].astype(jnp.float32),
                             cm[:, 0].astype(jnp.float32),
                             dt[:, 0], a_neg)
        y = y[:, None]                                   # (B,1,H,P)
        new_cache = {"state": hstate, "conv": window[:, 1:]}

    y = y + (p["D"][:, None] * (xh if cache is None else xh[:, None])
             .astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, s, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype))
    return proj(p["out"], y, flgw, plan=plan_of(plans, "out")), new_cache
