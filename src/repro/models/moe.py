"""Token-choice top-k Mixture-of-Experts with capacity-bounded dispatch.

Gather-based dispatch (megablocks-style, no (T, E, C) one-hot tensors): sort
token assignments by expert, take the first ``capacity`` per expert, run a
batched per-expert FFN einsum, and combine with router weights. Scales to
arctic's 128 experts. Expert weights are stacked (E, ...) so the expert axis
shards over the mesh (EP).

This mirrors the FLGW compact path in ``repro.core.grouped`` — both are
capacity-balanced gather → block compute → scatter pipelines; the MoE router
plays the role of the IG matrix, the expert axis the role of groups.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.flgw import FLGWConfig, init_grouping
from repro.models.layers import plan_of, proj


def moe_init(key, cfg, *, flgw: Optional[FLGWConfig] = None):
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 5)
    std = d ** -0.5
    params = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * std
                   ).astype(jnp.float32),
        "up": {"w": (jax.random.normal(ks[1], (e, d, ff), jnp.float32) * std
                     ).astype(cfg.dtype)},
        "gate": {"w": (jax.random.normal(ks[2], (e, d, ff), jnp.float32) * std
                       ).astype(cfg.dtype)},
        "down": {"w": (jax.random.normal(ks[3], (e, ff, d), jnp.float32)
                       * ff ** -0.5).astype(cfg.dtype)},
    }
    specs = {
        "router": ("embed", None),
        "up": {"w": ("expert", "embed", "ffn")},
        "gate": {"w": ("expert", "embed", "ffn")},
        "down": {"w": ("expert", "ffn", "embed")},
    }
    if flgw is not None and flgw.groups > 1:
        # FLGW composes per-expert: one IG/OG pair per expert FFN projection.
        gk = jax.random.split(ks[4], 3)
        for i, name in enumerate(("up", "gate")):
            g = jax.vmap(lambda k: init_grouping(k, d, ff, flgw.groups))(
                jax.random.split(gk[i], e))
            params[name]["ig"], params[name]["og"] = g["ig"], g["og"]
            specs[name]["ig"] = ("expert", "embed", None)
            specs[name]["og"] = ("expert", None, "ffn")
        g = jax.vmap(lambda k: init_grouping(k, ff, d, flgw.groups))(
            jax.random.split(gk[2], e))
        params["down"]["ig"], params["down"]["og"] = g["ig"], g["og"]
        specs["down"]["ig"] = ("expert", "ffn", None)
        specs["down"]["og"] = ("expert", None, "embed")
    return params, specs


def _expert_ffn(p, xe, flgw, plans=None):
    """xe: (E, C, d) -> (E, C, d), per-expert gated MLP.

    ``plans``: the layer's plan subtree — (E,)-stacked GroupPlans per
    up/gate/down projection, vmapped alongside the stacked expert params.
    """
    if flgw is not None and flgw.enabled and "ig" in p["up"]:
        def one(pu, pg, pd, x, pl):
            up = proj(pu, x, flgw, plan=plan_of(pl, "up"))
            up = jax.nn.gelu(proj(pg, x, flgw, plan=plan_of(pl, "gate"))) * up
            return proj(pd, up, flgw, plan=plan_of(pl, "down"))
        if plans:
            return jax.vmap(one)(p["up"], p["gate"], p["down"], xe, plans)
        return jax.vmap(lambda pu, pg, pd, x: one(pu, pg, pd, x, None))(
            p["up"], p["gate"], p["down"], xe)
    up = jnp.einsum("ecd,edf->ecf", xe, p["up"]["w"])
    gate = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["gate"]["w"]))
    return jnp.einsum("ecf,efd->ecd", up * gate, p["down"]["w"])


def moe(p, x, cfg, *, flgw: Optional[FLGWConfig] = None,
        dropless: bool = False, plans=None):
    """x: (B, S, d) -> (B, S, d). Returns (out, aux_loss).

    ``plans``: this MoE layer's entry of a cached PlanState (per-expert
    stacked GroupPlans; None falls back to per-call re-encoding).

    ``dropless=True`` sets per-expert capacity to the worst case (t·k) so
    no token is ever dropped — used on the decode path, where a dropped
    token would silently corrupt that sequence's cache/state forever.
    Training keeps the capacity-bounded dispatch (static shapes, bounded
    memory; drops are the standard trade).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"])            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, k)                   # (T, k)
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    # Load-balancing auxiliary loss (Switch-style).
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(gate_e[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    if dropless:
        cap = t * k
    else:
        cap = int(max(1, round(t * k / e * cfg.capacity_factor)))
        cap = min(cap, t)

    # Sort (token, slot) assignments by expert; first `cap` per expert kept.
    flat_e = gate_e.reshape(-1)                                # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = gate_w.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # position of each sorted entry within its expert run
    pos_in_e = jnp.arange(t * k) - jnp.searchsorted(se, se, side="left")
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)       # overflow -> drop

    tok_of_slot = jnp.full((e * cap + 1,), t, jnp.int32).at[slot].set(
        st, mode="drop")[:-1]                                  # (E*C,)
    w_of_slot = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        sw, mode="drop")[:-1]

    xe = jnp.take(xf, jnp.minimum(tok_of_slot, t - 1), axis=0)
    xe = jnp.where((tok_of_slot < t)[:, None], xe, 0).reshape(e, cap, d)
    ye = _expert_ffn(p, xe, flgw, plans).reshape(e * cap, d)
    ye = ye * w_of_slot[:, None].astype(ye.dtype)

    out = (jnp.zeros((t + 1, d), x.dtype)
           .at[tok_of_slot].add(ye, mode="drop")[:-1])
    return out.reshape(b, s, d), aux
