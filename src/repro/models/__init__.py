from repro.models.config import ModelConfig, SlotSpec, param_count, active_param_count  # noqa: F401
from repro.models.transformer import lm_init, lm_apply, init_cache  # noqa: F401
