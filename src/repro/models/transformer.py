"""Pattern-scanned transformer assembly for every assigned architecture.

The stack is ``lax.scan`` over ``n_blocks`` macro-blocks; inside the body the
``period`` slots of ``cfg.pattern`` are unrolled with their static types
(attn/ssm mixer, window size, mlp/moe/moe_dense FFN, optional cross-attn).
Per-slot parameters and KV/SSM caches are stacked on axis 0 and scanned.
This keeps HLO size O(period), not O(n_layers) — critical for compiling 10
architectures × 2 meshes on one host.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import encoder as planenc
from repro.core.flgw import FLGWConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig, SlotSpec
from repro.models.layers import (embed, embed_init, mlp, mlp_init, plan_of,
                                 rmsnorm, rmsnorm_init, softcap, unembed)
from repro.sharding.partition import constrain


def _flgw_cfg(cfg: ModelConfig, target: str) -> Optional[FLGWConfig]:
    if not cfg.flgw_on(target):
        return None
    return FLGWConfig(groups=cfg.flgw_groups, path=cfg.flgw_path)


def encode_plans(params, cfg: ModelConfig) -> planenc.PlanState:
    """One OSEL-analogue pass over the LM stack's FLGW projections.

    Plans for the scanned decoder blocks come back stacked along the
    ``n_blocks`` axis (mirroring the stacked params) and ride the block
    scan as per-block xs; the empty state is returned unless the compact
    ``grouped`` path is active.
    """
    if cfg.flgw_groups <= 1 or cfg.flgw_path != "grouped":
        return planenc.empty_state()
    return planenc.encode_plans(
        params, FLGWConfig(groups=cfg.flgw_groups, path=cfg.flgw_path))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _slot_init(key, cfg: ModelConfig, slot: SlotSpec):
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    p["norm1"], s["norm1"] = rmsnorm_init(cfg.d_model)
    if slot.mixer == "attn":
        p["mixer"], s["mixer"] = attn_mod.attn_init(
            ks[0], cfg, flgw=_flgw_cfg(cfg, "attn"))
    else:
        p["mixer"], s["mixer"] = ssm_mod.ssm_init(
            ks[0], cfg, flgw=_flgw_cfg(cfg, "ssm"))
    if slot.cross:
        p["norm_x"], s["norm_x"] = rmsnorm_init(cfg.d_model)
        p["cross"], s["cross"] = attn_mod.attn_init(
            ks[1], cfg, flgw=_flgw_cfg(cfg, "attn"))
    if slot.ffn == "none":
        return p, s
    p["norm2"], s["norm2"] = rmsnorm_init(cfg.d_model)
    if slot.ffn == "mlp":
        p["ffn"], s["ffn"] = mlp_init(
            ks[2], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
            flgw=_flgw_cfg(cfg, "mlp"), dtype=cfg.dtype)
    else:
        p["moe"], s["moe"] = moe_mod.moe_init(
            ks[3], cfg, flgw=_flgw_cfg(cfg, "moe"))
        if slot.ffn == "moe_dense":
            p["ffn"], s["ffn"] = mlp_init(
                ks[4], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                flgw=_flgw_cfg(cfg, "mlp"), dtype=cfg.dtype)
    return p, s


def _stacked_slot_init(key, cfg: ModelConfig, slot: SlotSpec, n: int):
    keys = jax.random.split(key, n)
    spec_box = {}

    def init_one(k):
        p, s = _slot_init(k, cfg, slot)
        spec_box["spec"] = s            # static — captured during tracing
        return p

    params = jax.vmap(init_one)(keys)
    # prepend the "layers" (scan) axis to every leaf spec
    spec = jax.tree.map(lambda a: ("layers",) + tuple(a), spec_box["spec"],
                        is_leaf=lambda a: isinstance(a, tuple)
                        and all(isinstance(x, (str, type(None))) for x in a))
    return params, spec


def _blocks_init(key, cfg: ModelConfig, pattern, n_blocks: int):
    params, specs = {}, {}
    keys = jax.random.split(key, len(pattern))
    for i, slot in enumerate(pattern):
        params[f"slot{i}"], specs[f"slot{i}"] = _stacked_slot_init(
            keys[i], cfg, slot, n_blocks)
    return params, specs


def lm_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    params, specs = {}, {}
    params["embed"], specs["embed"] = embed_init(
        ks[0], cfg.vocab, cfg.d_model, cfg.dtype)
    params["blocks"], specs["blocks"] = _blocks_init(
        ks[1], cfg, cfg.pattern, cfg.n_blocks)
    params["final_norm"], specs["final_norm"] = rmsnorm_init(cfg.d_model)
    if cfg.encoder_layers:
        enc_slot = SlotSpec(mixer="attn", window=0, ffn="mlp", causal=False)
        params["encoder"], specs["encoder"] = _blocks_init(
            ks[2], cfg, (enc_slot,), cfg.encoder_layers)
        params["enc_norm"], specs["enc_norm"] = rmsnorm_init(cfg.d_model)
    return params, specs


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def _slot_apply(p, x, positions, cfg: ModelConfig, slot: SlotSpec, *,
                cache=None, pos=None, encoder_out=None, prefix_len=0,
                q_chunk=512, banded=False, ssd_unroll=False,
                moe_dropless=False, attn_identity=False, plans=None):
    """``plans``: this slot's entry of the (sliced) PlanState — cached
    FLGW metadata for *every* FLGW target the slot carries: the
    attention/SSM mixer, the cross-attention, the MoE experts and the
    ``ffn`` projections all consume their own plan subtree, so no mixer
    ever falls back to per-call re-encoding when a PlanState is supplied.
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if slot.mixer == "attn":
        c = None
        if cache is not None:
            c = {"k": cache["k"], "v": cache["v"], "pos": pos}
        h, nc = attn_mod.attention(
            p["mixer"], h, positions, cfg, window=slot.window,
            causal=slot.causal, prefix_len=prefix_len, cache=c,
            q_chunk=q_chunk, banded=banded, flash=cfg.use_flash,
            core_identity=attn_identity, flgw=_flgw_cfg(cfg, "attn"),
            plans=plan_of(plans, "mixer"))
        if nc is not None:
            new_cache.update({"k": nc["k"], "v": nc["v"]})
    else:
        h, nc = ssm_mod.ssm(p["mixer"], h, cfg, cache=cache and
                            {"state": cache["state"], "conv": cache["conv"]},
                            chunk=cfg.ssm_chunk,
                            flgw=_flgw_cfg(cfg, "ssm"), unroll=ssd_unroll,
                            plans=plan_of(plans, "mixer"))
        if nc is not None:
            new_cache.update(nc)
    x = x + h
    if slot.cross:
        h = rmsnorm(p["norm_x"], x, cfg.norm_eps)
        h, _ = attn_mod.attention(
            p["cross"], h, positions, cfg, causal=False, kv_x=encoder_out,
            q_chunk=q_chunk, flgw=_flgw_cfg(cfg, "attn"),
            plans=plan_of(plans, "cross"))
        x = x + h
    if slot.ffn == "none":     # pure-SSM blocks (mamba2) have no FFN
        return x, aux, new_cache
    h = rmsnorm(p["norm2"], x, cfg.norm_eps)
    if slot.ffn == "mlp":
        h = mlp(p["ffn"], h, _flgw_cfg(cfg, "mlp"),
                plans=plan_of(plans, "ffn"))
    else:
        h, a = moe_mod.moe(p["moe"], h, cfg, flgw=_flgw_cfg(cfg, "moe"),
                           dropless=moe_dropless or cache is not None,
                           plans=plan_of(plans, "moe"))
        aux = aux + a
        if slot.ffn == "moe_dense":
            h = h + mlp(p["ffn"], rmsnorm(p["norm2"], x, cfg.norm_eps),
                        _flgw_cfg(cfg, "mlp"), plans=plan_of(plans, "ffn"))
    return x + h, aux, new_cache


def _apply_blocks(params, cfg: ModelConfig, pattern, x, positions, *,
                  caches=None, pos=None, encoder_out=None, prefix_len=0,
                  q_chunk=512, banded=False, remat=False, ssd_unroll=False,
                  unroll_blocks=False, moe_dropless=False,
                  attn_identity=False, plans=None):
    has_cache = caches is not None
    plans = plans or {}   # nested dict: slot{i} -> ffn -> stacked GroupPlans

    def body(carry, xs):
        x, aux = carry
        x = constrain(x, ("batch", None, None))   # keep batch data-parallel
        if has_cache:
            block_p, block_c, block_pl = xs
        else:
            (block_p, block_pl), block_c = xs, None
        new_c = {}
        for i, slot in enumerate(pattern):
            c_i = None if block_c is None else block_c.get(f"slot{i}")
            x, a, nc = _slot_apply(
                block_p[f"slot{i}"], x, positions, cfg, slot, cache=c_i,
                pos=pos, encoder_out=encoder_out, prefix_len=prefix_len,
                q_chunk=q_chunk, banded=banded, ssd_unroll=ssd_unroll,
                moe_dropless=moe_dropless, attn_identity=attn_identity,
                plans=plan_of(block_pl, f"slot{i}"))
            aux = aux + a
            if nc:
                new_c[f"slot{i}"] = nc
        return (x, aux), (new_c if new_c else None)

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    aux0 = jnp.zeros((), jnp.float32)
    # plans ride the scan as per-block xs ({} contributes no leaves — the
    # stacked GroupPlans slice alongside their stacked params)
    xs = (params, caches, plans) if has_cache else (params, plans)

    if unroll_blocks:
        # Straight-line block loop — the dry-run cost variant. HLO cost
        # analysis counts a while-loop body once (fwd AND the reverse-scan
        # bwd), so the cost program must contain no loops at all.
        carry, outs = (x, aux0), []
        nb = jax.tree.leaves(params)[0].shape[0]
        for i in range(nb):
            xs_i = jax.tree.map(lambda a: a[i], xs)
            carry, o = body(carry, xs_i)
            outs.append(o)
        (x, aux) = carry
        new_caches = (None if outs[0] is None
                      else jax.tree.map(lambda *ls: jnp.stack(ls), *outs))
        return x, aux, new_caches

    (x, aux), new_caches = jax.lax.scan(body, (x, aux0), xs)
    return x, aux, new_caches


def lm_apply(params, cfg: ModelConfig, tokens, positions, *,
             patch_embeds=None, frames=None, cache=None, q_chunk=512,
             banded=False, remat=None, return_hidden=False,
             ssd_unroll=False, unroll_blocks=False, moe_dropless=False,
             attn_identity=False, plans=None):
    """Forward pass. Returns (logits, aux_loss, new_cache).

    tokens: (B, S) int32; positions: (B, S) int32.
    patch_embeds: (B, prefix, d) VLM stub prefix (prefill only).
    frames: (B, T, d) audio-stub encoder input (whisper).
    cache: decode caches from ``init_cache``.
    plans: cached FLGW metadata from :func:`encode_plans` (PlanState or its
    raw dict). When None, a ``plans`` entry riding the decode cache (see
    ``init_cache(..., params=...)``) is consumed instead — the serving
    contract: the PlanState lives beside the KV/SSM caches, encoded once
    at prefill and reused by every decode step. With neither, the grouped
    path falls back to per-projection re-encoding.
    return_hidden: skip unembedding — the training loss computes logits in
    sequence chunks (the full (B, S, vocab) tensor at 256k vocab never fits).
    """
    remat = cfg.remat if remat is None else remat
    if plans is None and cache is not None:
        plans = cache.get("plans")
    if isinstance(plans, planenc.PlanState):
        plans = plans.plans
    plans = plans or {}
    x = embed(params["embed"], tokens, cfg.d_model).astype(cfg.dtype)
    prefix_len = 0
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(cfg.dtype), x], axis=1)
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2])
        prefix_len = patch_embeds.shape[1]

    encoder_out = None
    if cfg.encoder_layers:
        if frames is not None:
            enc_slot = SlotSpec(mixer="attn", window=0, ffn="mlp", causal=False)
            enc_pos = jnp.broadcast_to(
                jnp.arange(frames.shape[1], dtype=jnp.int32)[None],
                frames.shape[:2])
            eo, _, _ = _apply_blocks(
                params["encoder"], cfg, (enc_slot,),
                frames.astype(cfg.dtype), enc_pos, q_chunk=q_chunk,
                remat=remat, ssd_unroll=ssd_unroll,
                unroll_blocks=unroll_blocks, plans=plans.get("encoder"))
            encoder_out = rmsnorm(params["enc_norm"], eo, cfg.norm_eps)
            # Encoder self-attn must be bidirectional: handled by window=0 &
            # causal mask relaxation below (prefix over the whole stream).
        elif cache is not None:
            encoder_out = cache["encoder_out"]

    pos = None if cache is None else cache["pos"]
    slot_caches = None if cache is None else cache["blocks"]
    x, aux, new_slot_caches = _apply_blocks(
        params["blocks"], cfg, cfg.pattern, x, positions, caches=slot_caches,
        pos=pos, encoder_out=encoder_out, prefix_len=prefix_len,
        q_chunk=q_chunk, banded=banded, remat=remat and cache is None,
        ssd_unroll=ssd_unroll, unroll_blocks=unroll_blocks,
        moe_dropless=moe_dropless, attn_identity=attn_identity,
        plans=plans.get("blocks"))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        out = x if prefix_len == 0 else x[:, prefix_len:]
    else:
        logits = unembed(params["embed"], x)
        out = softcap(logits.astype(jnp.float32), cfg.logit_softcap)

    new_cache = None
    if cache is not None:
        new_cache = {"pos": pos + tokens.shape[1], "blocks": new_slot_caches}
        if "plans" in cache:
            # plans ride the cache unchanged — params are frozen *within*
            # a request; across requests (online tuning) the serving loop
            # certifies them via refresh_cache_plans at the boundary
            new_cache["plans"] = cache["plans"]
        if encoder_out is not None:
            new_cache["encoder_out"] = encoder_out
    return out, aux, new_cache


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def _cache_len(slot: SlotSpec, max_seq: int) -> int:
    """KV length of one slot: sliding-window slots only ever see ``window``
    positions, so their ring buffer is bounded — O(window) memory per layer
    regardless of context length."""
    if slot.window > 0:
        return min(max_seq, slot.window)
    return max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None, *, params=None, per_slot: bool = False,
               compact: bool | None = None) -> dict:
    """Decode caches, stacked (n_blocks, ...) per slot.

    ``params``: pass the model params to cache a :class:`~repro.core.
    encoder.PlanState` beside the KV/SSM caches (``cache["plans"]``) on
    the FLGW grouped path — the one-encode-per-serve contract: prefill
    builds the plans here, every decode step consumes them through
    ``lm_apply``, and they ride the returned cache unchanged. Without
    params (or off the grouped path) ``cache["plans"]`` is ``()`` and
    grouped projections fall back to per-call re-encoding.

    ``compact``: also attach the compact weights (``GroupPlan.wc`` — the
    weight half of the OSEL encode output) so decode steps consume the
    fused kernel path with zero per-call W gathers. Defaults to on
    whenever ``params`` is given; pass ``False`` for a layout-only
    PlanState (e.g. to measure the unfused path). The attached weights
    snapshot this params version — re-attach at params boundaries
    (:func:`refresh_cache_plans` does, even when the layout signature
    certifies).

    ``per_slot``: allocate ``cache["pos"]`` as a (batch,) vector instead
    of a scalar — each batch row becomes an independent request *slot* at
    its own stream offset. This is the continuous-batching layout
    (``repro.serving``): requests join and leave the decode batch
    mid-flight, and :func:`reset_slots` recycles a freed row for a fresh
    request. The lockstep scalar layout stays the default.
    """
    dtype = dtype or cfg.dtype
    nb = cfg.n_blocks
    blocks = {}
    for i, slot in enumerate(cfg.pattern):
        if slot.mixer == "attn":
            kv = (nb, batch, _cache_len(slot, max_seq), cfg.n_kv_heads,
                  cfg.head_dim)
            blocks[f"slot{i}"] = {
                "k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}
        else:
            conv_ch = cfg.d_inner + 2 * cfg.ssm_state
            blocks[f"slot{i}"] = {
                "state": jnp.zeros((nb, batch, cfg.ssm_heads,
                                    cfg.ssm_head_dim, cfg.ssm_state),
                                   jnp.float32),
                "conv": jnp.zeros((nb, batch, cfg.conv_width - 1, conv_ch),
                                  dtype)}
    pos_shape = (batch,) if per_slot else ()
    cache = {"pos": jnp.zeros(pos_shape, jnp.int32), "blocks": blocks}
    plans = ()
    if params is not None:
        state = encode_plans(params, cfg)
        if state.plans:               # grouped path: PlanState beside the KV
            if compact is None or compact:
                state = planenc.attach_compact(state, params)
            plans = state
    cache["plans"] = plans
    if cfg.encoder_layers:
        cache["encoder_out"] = jnp.zeros(
            (batch, cfg.num_frames, cfg.d_model), dtype)
    return cache


def refresh_cache_plans(params, cfg: ModelConfig, cache: dict) -> dict:
    """Request-boundary staleness check for the serving PlanState.

    ``cache["plans"]`` is encoded once (``init_cache(..., params=...)``)
    and trusted by every decode step — correct while params are frozen,
    stale the moment online tuning moves them between requests. Call this
    at the prefill/serve boundary of each request: it re-hashes the
    current params' grouping layout (:func:`repro.core.encoder.
    plan_signature`) against the cached signature and re-encodes only on
    a mismatch, so the per-request cost is ~half an encode when nothing
    moved and exactly one encode when it did. Caches without a PlanState
    (off the grouped path) pass through untouched. Jit-friendly — compose
    it into a request-setup step or call it eagerly between requests.
    """
    plans = cache.get("plans")
    if not isinstance(plans, planenc.PlanState) or not plans.plans:
        return cache
    fresh = planenc.refresh_if_stale(
        params, plans, encode=lambda: encode_plans(params, cfg))
    return dict(cache, plans=fresh)


def reset_slots(cache: dict, mask) -> dict:
    """Recycle batch rows of a per-slot decode cache for fresh requests.

    ``mask``: (batch,) bool — True rows are cleared: their stream offset
    returns to 0 and their SSM recurrent/conv state zeroes (it integrates
    every step, so the previous occupant would leak into the newcomer).
    KV buffers need no clearing — resetting ``pos`` invalidates every ring
    index (each maps to a negative absolute position until rewritten), and
    masked logits contribute exactly 0 after the softmax. False rows pass
    through bitwise-untouched (the slot-isolation contract, pinned in
    tests/test_scheduler.py). Requires a ``per_slot=True`` cache;
    jit-friendly.
    """
    pos = cache["pos"]
    if jnp.ndim(pos) != 1:
        raise ValueError(
            "reset_slots needs a per-slot cache (init_cache(per_slot=True)); "
            "this cache has a scalar shared position")
    mask = jnp.asarray(mask, bool)
    out = dict(cache, pos=jnp.where(mask, 0, pos))
    blocks = {}
    for name, c in cache["blocks"].items():
        nc = dict(c)
        for leaf in ("state", "conv"):
            if leaf in c:
                m = mask.reshape((1, -1) + (1,) * (c[leaf].ndim - 2))
                nc[leaf] = jnp.where(m, jnp.zeros((), c[leaf].dtype), c[leaf])
        blocks[name] = nc
    out["blocks"] = blocks
    return out


def plan_specs(cfg: ModelConfig, *, compact: bool = False):
    """Logical spec tree of the stack's cached PlanState (replicated: the
    compact metadata is small int/bool tensors consumed whole by every
    shard). ``()`` off the grouped path — matching ``init_cache`` /
    ``TrainState.plans``. ``compact=True`` mirrors a weight-attached
    state (``init_cache(params=...)``'s default), whose ``wc`` leaves are
    likewise replicated."""
    if cfg.flgw_groups <= 1 or cfg.flgw_path != "grouped":
        return ()

    def _abstract(k):
        state = encode_plans(lm_init(k, cfg)[0], cfg)
        if compact:
            state = planenc.attach_compact(state, lm_init(k, cfg)[0])
        return state
    aplans = jax.eval_shape(_abstract, jax.random.PRNGKey(0))
    return jax.tree.map(lambda a: (None,) * a.ndim, aplans)


def cache_specs(cfg: ModelConfig, *, per_slot: bool = False) -> dict:
    """Logical-axis spec tree mirroring ``init_cache``.

    KV is sharded over the *sequence* dim on the model axis ("seq_kv") —
    sequence length is always large and divisible, unlike GQA KV head
    counts (4–16), and batch=1 long-context cells can't use the data axis.
    This is the flash-decoding-style layout: each model shard scores its
    slice of the KV cache and the tiny (B, H, hd) partial results reduce.
    """
    blocks = {}
    for i, slot in enumerate(cfg.pattern):
        if slot.mixer == "attn":
            kv = ("layers", "batch", "seq_kv", "kv_heads", None)
            blocks[f"slot{i}"] = {"k": kv, "v": kv}
        else:
            blocks[f"slot{i}"] = {
                "state": ("layers", "batch", "heads", None, None),
                "conv": ("layers", "batch", None, "ffn")}
    specs = {"pos": ("batch",) if per_slot else (), "blocks": blocks,
             "plans": plan_specs(cfg, compact=True)}
    if cfg.encoder_layers:
        specs["encoder_out"] = ("batch", None, None)
    return specs
