"""Unified model configuration covering the 10 assigned architectures.

Layer heterogeneity (local/global attention, MoE cadence, Mamba/attention
interleave) is expressed as a repeating *pattern* of length ``period``; the
stack is compiled as ``lax.scan`` over ``n_layers // period`` macro-blocks
with the ``period`` slots unrolled inside the body — small HLO, fast compile,
exact per-layer types.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SlotSpec:
    """Static type of one layer slot inside the repeating pattern."""
    mixer: str = "attn"          # "attn" | "ssm"
    window: int = 0              # 0 = global attention; >0 = sliding window
    ffn: str = "mlp"             # "mlp" | "moe" | "moe_dense" (residual MoE)
    cross: bool = False          # add cross-attention (decoder of enc-dec)
    causal: bool = True          # False for encoder (bidirectional) stacks


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    pattern: Tuple[SlotSpec, ...] = (SlotSpec(),)
    # attention details
    logit_softcap: float = 0.0   # final-logit softcap (gemma2)
    attn_softcap: float = 0.0    # attention-logit softcap (gemma2)
    rope_theta: float = 10000.0
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2 / jamba)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256         # SSD chunk length (perf knob)
    # enc-dec (whisper)
    encoder_layers: int = 0
    num_frames: int = 0          # audio-stub source positions
    # vlm (paligemma)
    prefix_len: int = 0          # image-patch prefix length (stub embeddings)
    # activation / norm
    gated_mlp: bool = True
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16
    # FLGW (the paper's technique)
    flgw_groups: int = 1
    flgw_path: str = "masked"    # dense | masked | grouped
    flgw_targets: Tuple[str, ...] = ("mlp",)   # mlp | attn | moe
    # training
    remat: bool = True
    use_flash: bool = False     # fused Pallas attention core (perf path)

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_blocks(self) -> int:
        assert self.n_layers % self.period == 0, \
            f"{self.name}: {self.n_layers} % {self.period} != 0"
        return self.n_layers // self.period

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def flgw_on(self, target: str) -> bool:
        return self.flgw_groups > 1 and self.flgw_path != "dense" \
            and target in self.flgw_targets

    def with_updates(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def _count(cfg: ModelConfig, experts_per_moe: int) -> int:
    """Parameter count with MoE slots counted as ``experts_per_moe`` FFNs."""
    d, h = cfg.d_model, cfg.head_dim
    total = cfg.vocab * d                              # embeddings
    if not cfg.tie_embeddings:
        total += cfg.vocab * d

    def attn_params():
        return d * h * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * h * d

    def mlp_params(ff):
        return d * ff * (3 if cfg.gated_mlp else 2)

    def ssm_params():
        di, ns = cfg.d_inner, cfg.ssm_state
        # in_proj (x, z, B, C, dt), conv, out_proj, A/D/dt_bias
        return (d * (2 * di + 2 * ns + cfg.ssm_heads)
                + cfg.conv_width * (di + 2 * ns) + di * d + 3 * cfg.ssm_heads)

    per_block = 0
    for slot in cfg.pattern:
        per_block += attn_params() if slot.mixer == "attn" else ssm_params()
        if slot.cross:
            per_block += attn_params()
        if slot.ffn == "none":
            pass
        elif slot.ffn == "mlp":
            per_block += mlp_params(cfg.d_ff)
        else:  # moe | moe_dense
            per_block += experts_per_moe * mlp_params(cfg.moe_d_ff or cfg.d_ff)
            per_block += d * cfg.n_experts             # router
            if slot.ffn == "moe_dense":
                per_block += mlp_params(cfg.d_ff)      # dense residual branch
        per_block += 4 * d                             # norms (approx)
    total += cfg.n_blocks * per_block
    if cfg.encoder_layers:
        total += cfg.encoder_layers * (attn_params() + mlp_params(cfg.d_ff)
                                       + 4 * d)
    return int(total)


def param_count(cfg: ModelConfig) -> int:
    """Total parameters (for 6·N·D model-FLOPs of dense models)."""
    return _count(cfg, cfg.n_experts)


def active_param_count(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: only top_k experts fire)."""
    return _count(cfg, cfg.top_k if cfg.n_experts else 0)
