"""GQA attention: RoPE, sliding window, logit softcap, prefix-LM masking,
KV-cache decode, cross-attention — query-chunked for bounded memory.

The training/prefill path scans over query chunks so the materialized logit
tile is (B, Hkv, q_per_kv, Cq, T) instead of the full S×T square — this keeps
32k-sequence prefill inside per-device HBM without a fused kernel, while HLO
FLOP accounting stays exact for the roofline. ``banded=True`` additionally
restricts each query chunk of a sliding-window layer to its reachable KV band
(exact, FLOPs ÷ ~S/window) — used by the perf path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.flgw import FLGWConfig
from repro.models.layers import dense_init, plan_of, proj, rope, softcap

NEG_INF = -2.3819763e38  # == jnp.finfo(jnp.float32).min-ish, matches XLA


def attn_init(key, cfg, *, flgw: Optional[FLGWConfig] = None):
    d, h = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    params, specs = {}, {}
    params["q"], specs["q"] = dense_init(
        ks[0], d, cfg.n_heads * h, flgw=flgw, axes=("embed", "heads"),
        dtype=cfg.dtype)
    params["k"], specs["k"] = dense_init(
        ks[1], d, cfg.n_kv_heads * h, flgw=flgw, axes=("embed", "kv_heads"),
        dtype=cfg.dtype)
    params["v"], specs["v"] = dense_init(
        ks[2], d, cfg.n_kv_heads * h, flgw=flgw, axes=("embed", "kv_heads"),
        dtype=cfg.dtype)
    params["o"], specs["o"] = dense_init(
        ks[3], cfg.n_heads * h, d, flgw=flgw, axes=("heads", "embed"),
        dtype=cfg.dtype)
    return params, specs


def _mask(q_pos, k_pos, *, causal: bool, window: int, prefix_len: int,
          k_valid=None):
    """(..., Sq, Sk) boolean allowed-attention mask from position vectors."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    if causal:
        allowed = k <= q
        if prefix_len > 0:
            allowed = allowed | ((k < prefix_len) & (q < prefix_len))
    else:
        allowed = jnp.ones(jnp.broadcast_shapes(q.shape, k.shape), bool)
    if window > 0:
        allowed = allowed & (k > q - window)
    if k_valid is not None:
        allowed = allowed & k_valid[..., None, :]
    return allowed


def _attend(q, k, v, mask, cfg):
    """q: (B, Sq, G, Q, D); k/v: (B, Sk, G, D); mask: (B, Sq, Sk) or (Sq, Sk)."""
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bsgqd,btgd->bgqst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if cfg.attn_softcap > 0:
        logits = softcap(logits, cfg.attn_softcap)
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bgqst,btgd->bsgqd", probs, v)
    return out


def _split_heads(x, n_kv, q_per_kv, hd):
    b, s = x.shape[:2]
    return x.reshape(b, s, n_kv, q_per_kv, hd)


def attention(p, x, positions, cfg, *, window: int = 0, causal: bool = True,
              prefix_len: int = 0, kv_x: Optional[jax.Array] = None,
              cache: Optional[dict] = None, q_chunk: int = 512,
              banded: bool = False, flash: bool = False,
              core_identity: bool = False,
              flgw: Optional[FLGWConfig] = None, plans=None):
    """Returns (out, new_cache).

    * training/prefill: ``cache is None`` — full-sequence, query-chunked.
    * decode: ``cache = {"k","v","pos"}`` — insert one (or few) tokens at
      ``cache["pos"]`` and attend over the cache.
    * cross-attention: ``kv_x`` given — keys/values from the encoder stream,
      no causal mask, no RoPE on k (positions of memory are absolute).

    ``plans``: this attention layer's entry of a cached PlanState — one
    GroupPlan per q/k/v/o projection on the FLGW grouped path (None falls
    back to per-call re-encoding inside ``proj``).
    """
    b, s, _ = x.shape
    hd, n_kv, qpk = cfg.head_dim, cfg.n_kv_heads, cfg.q_per_kv
    q = proj(p["q"], x, flgw, plan=plan_of(plans, "q")
             ).reshape(b, s, n_kv, qpk, hd)
    src = x if kv_x is None else kv_x
    k = proj(p["k"], src, flgw, plan=plan_of(plans, "k")
             ).reshape(b, src.shape[1], n_kv, hd)
    v = proj(p["v"], src, flgw, plan=plan_of(plans, "v")
             ).reshape(b, src.shape[1], n_kv, hd)

    if kv_x is None:
        q = rope(q.reshape(b, s, n_kv * qpk, hd), positions,
                 cfg.rope_theta).reshape(b, s, n_kv, qpk, hd)
        k = rope(k, positions if cache is None else positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # Decode: ring-buffer write at ``pos % L``. Windowed slots allocate
        # L = min(max_seq, window) (init_cache), so sliding-window layers
        # keep O(window) memory at any context length — this is what makes
        # the 500k-context cells runnable for SWA/local-attention archs.
        # When L covers the whole stream, pos % L == pos and this reduces to
        # the plain append-at-pos cache. Single-token writes only (s == 1
        # in the decode cells); multi-token prefill goes through the
        # cache-free path.
        pos = cache["pos"]
        t = cache["k"].shape[1]
        if jnp.ndim(pos) == 0:
            # lockstep cache: every batch row shares one stream offset
            write = pos % t
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, write,
                                                     axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, write,
                                                     axis=1)
            idx = jnp.arange(t, dtype=jnp.int32)
            # Absolute position held by each ring slot after the write: the
            # largest p ≤ pos with p ≡ idx (mod L); negative ⇒ never written.
            k_pos = (pos - jnp.mod(pos - idx, t))[None]
            k_valid = (k_pos >= 0)
        else:
            # per-slot cache (``init_cache(per_slot=True)``): ``pos`` is
            # (B,) — each batch row is an independent request stream at its
            # own offset, the continuous-batching contract. Same ring-buffer
            # semantics, applied row-wise; a slot reset to pos=0 invalidates
            # its stale KV for free (every unwritten ring index maps to a
            # negative absolute position below).
            if s != 1:
                raise ValueError(
                    "per-slot decode caches take single-token steps "
                    f"(got {s} tokens); multi-token prefill goes through "
                    "the cache-free path one token at a time")
            write = pos % t                                    # (B,)
            rows = jnp.arange(b)
            ck = cache["k"].at[rows, write].set(k[:, 0])
            cv = cache["v"].at[rows, write].set(v[:, 0])
            idx = jnp.arange(t, dtype=jnp.int32)
            k_pos = pos[:, None] - jnp.mod(pos[:, None] - idx[None], t)
            k_valid = k_pos >= 0                               # (B, L)
        new_cache = {"k": ck, "v": cv, "pos": pos + s}
        mask = _mask(positions, k_pos, causal=causal, window=window,
                     prefix_len=prefix_len, k_valid=k_valid)
        out = _attend(q, ck, cv, mask, cfg)
        out = out.reshape(b, s, n_kv * qpk * hd)
        return proj(p["o"], out, flgw, plan=plan_of(plans, "o")), new_cache

    if core_identity and cache is None:
        # Dry-run cost variant: skip ONLY the attention core (projections,
        # RoPE stay). Subtracting this variant's measured cost from the
        # normal one isolates the core's HLO contribution, which the flash
        # accounting replaces with the fused-kernel analytic model.
        out = q.reshape(b, s, -1)
        return proj(p["o"], out, flgw, plan=plan_of(plans, "o")), None

    # Training / prefill: fused Pallas path when applicable (self-attention,
    # positions are the plain 0..S-1 ramp, no bidirectional prefix). The
    # kernel never materializes the (S, T) logits — see kernels/flash_attention.
    if (flash and kv_x is None and prefix_len == 0 and causal):
        from repro.kernels.flash_attention.ops import flash_attention
        qf = q.reshape(b, s, n_kv * qpk, hd).transpose(0, 2, 1, 3)
        kf = k.transpose(0, 2, 1, 3)
        vf = v.transpose(0, 2, 1, 3)
        of = flash_attention(qf, kf, vf, True, window,
                             float(cfg.attn_softcap), None, 512, 512, None)
        out = of.transpose(0, 2, 1, 3).reshape(b, s, -1)
        return proj(p["o"], out, flgw, plan=plan_of(plans, "o")), None

    # Training / prefill: scan over query chunks for bounded memory.
    t = src.shape[1]
    k_pos_full = positions if kv_x is None else jnp.arange(t, dtype=jnp.int32)[None]
    if s <= q_chunk:
        mask = _mask(positions, k_pos_full, causal=causal and kv_x is None,
                     window=window, prefix_len=prefix_len)
        out = _attend(q, k, v, mask, cfg)
        return proj(p["o"], out.reshape(b, s, -1), flgw, plan=plan_of(plans, "o")), None

    if s % q_chunk != 0:   # e.g. VLM prefix extends S; pick a clean divisor
        q_chunk = next(c for c in range(q_chunk, 0, -1) if s % c == 0)
    n_chunks = s // q_chunk
    qc = q.reshape(b, n_chunks, q_chunk, n_kv, qpk, hd).transpose(1, 0, 2, 3, 4, 5)
    pc = positions.reshape(b, n_chunks, q_chunk).transpose(1, 0, 2)

    use_band = banded and window > 0 and kv_x is None
    band = None
    if use_band:
        # KV band reachable by one query chunk: window + chunk, rounded to
        # chunk granularity (exact — outside the band everything is masked).
        band = min(t, ((window + q_chunk - 1) // q_chunk + 1) * q_chunk)

    def body(carry, inp):
        ci, q_i, p_i = inp
        if use_band:
            start = jnp.maximum(ci * q_chunk + q_chunk - band, 0)
            k_i = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            v_i = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            kp_i = jax.lax.dynamic_slice_in_dim(k_pos_full, start, band,
                                                axis=-1)
        else:
            k_i, v_i, kp_i = k, v, k_pos_full
        m = _mask(p_i, kp_i, causal=causal and kv_x is None, window=window,
                  prefix_len=prefix_len)
        o = _attend(q_i, k_i, v_i, m, cfg)
        return carry, o

    idx = jnp.arange(n_chunks, dtype=jnp.int32)
    _, outs = jax.lax.scan(body, None, (idx, qc, pc))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, -1)
    return proj(p["o"], out, flgw, plan=plan_of(plans, "o")), None
