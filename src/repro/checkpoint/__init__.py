from repro.checkpoint.store import (save_checkpoint, restore_checkpoint,
                                    latest_step, list_steps, manifest_paths)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "list_steps", "manifest_paths"]
