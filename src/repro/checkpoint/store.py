"""Sharded, atomic, reshard-on-restore checkpoints.

Layout (one directory per step):

    <dir>/step_000123.tmp-<nonce>/   # written first
        manifest.json                # tree structure, shapes, dtypes, hashes
        arr_000000.npy ...           # one file per leaf
    <dir>/step_000123/               # atomic rename when complete

Fault-tolerance properties:

* **Atomicity** — a crash mid-write leaves only a ``.tmp-*`` directory,
  which restore ignores and the next save garbage-collects. The rename is
  the commit point.
* **Integrity** — the manifest stores a content hash per leaf; restore
  verifies before handing the tree to the optimizer.
* **Elastic restore** — arrays are saved *unsharded by logical leaf* and
  re-sharded on restore to whatever mesh/sharding the caller passes, so a
  512-chip checkpoint restores onto 256 chips (or a CPU test) unchanged.
  (At true 1000-node scale the npy writer swaps for a parallel object-store
  writer behind the same manifest format; the commit protocol is the same.)
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import time
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name, including ml_dtypes extras (bfloat16, fp8)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _tree_paths(tree) -> list[tuple[str, Any]]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), v) for kp, v in leaves]


def _hash(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


def save_checkpoint(ckpt_dir, step: int, tree, *, keep: int = 3) -> str:
    """Write one checkpoint; atomic commit via rename. Returns final path."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp-{os.getpid()}-{time.time_ns()}"
    tmp.mkdir()

    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(_tree_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"arr_{i:06d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"].append({
            "path": path, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "hash": _hash(arr)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))

    if final.exists():                      # crash-retry of the same step
        shutil.rmtree(final)
    tmp.rename(final)                       # commit point

    # GC: stale tmp dirs + old steps beyond ``keep``.
    for d in ckpt_dir.glob("step_*.tmp-*"):
        shutil.rmtree(d, ignore_errors=True)
    steps = list_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(ckpt_dir / f"step_{s:08d}", ignore_errors=True)
    return str(final)


def list_steps(ckpt_dir) -> list[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    out = []
    for d in ckpt_dir.glob("step_*"):
        if d.name.endswith(".json") or ".tmp-" in d.name:
            continue
        if (d / "manifest.json").exists():
            out.append(int(d.name.split("_")[1]))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def manifest_paths(ckpt_dir, *, step: Optional[int] = None) -> set:
    """Leaf keystr paths recorded in one checkpoint's manifest."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    return {e["path"] for e in manifest["leaves"]}


def restore_checkpoint(ckpt_dir, target_tree, *, step: Optional[int] = None,
                       shardings=None, verify: bool = True,
                       strict: bool = True):
    """Restore into the structure of ``target_tree``.

    ``shardings``: optional matching tree of NamedSharding — each leaf is
    device_put with its sharding (elastic reshard: works for any mesh).
    ``strict=False`` tolerates target leaves the manifest does not record
    — they keep the value already in ``target_tree`` — instead of raising.
    The main client is checkpoint *schema growth*: e.g. grouped
    ``TrainState``s grew derived ``plans`` leaves that pre-plans manifests
    lack (callers then recompute the kept leaves — see
    ``repro.train.state.restore_state``, which migrates such checkpoints
    and re-encodes the plans from the restored params).
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())

    by_path = {e["path"]: e for e in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    sh_flat = (jax.tree_util.tree_leaves(shardings)
               if shardings is not None else [None] * len(flat))
    missing = [jax.tree_util.keystr(kp) for kp, _ in flat
               if jax.tree_util.keystr(kp) not in by_path]
    if missing and strict:
        raise KeyError(
            f"{d} records {len(by_path)} leaves but the restore target has "
            f"{len(missing)} the manifest does not (e.g. {missing[0]}). "
            "If the target schema grew since the save (pre-plans grouped "
            "checkpoints lack TrainState.plans leaves), restore with "
            "strict=False and recompute the missing leaves, or use "
            "repro.train.state.restore_state which migrates and re-encodes "
            "plans automatically.")
    out = []
    for (kp, ref), sh in zip(flat, sh_flat):
        e = by_path.get(jax.tree_util.keystr(kp))
        if e is None:                    # strict=False: keep target's value
            out.append(jax.device_put(ref, sh) if sh is not None else ref)
            continue
        arr = np.load(d / e["file"])
        if arr.dtype.kind == "V":   # np.load loses ml_dtypes names (bf16)
            arr = arr.view(_np_dtype(e["dtype"]))
        if verify and _hash(arr) != e["hash"]:
            raise IOError(f"checkpoint corruption at {e['path']}")
        if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
            arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, out), step
