"""Sequence-chunked cross-entropy.

At 256k vocab the full (B, S, V) logit tensor of a train_4k cell is ~4 TB in
f32 — it must never exist. The loss scans over sequence chunks: each chunk
unembeds (chunk-local logits), applies the gemma softcap, reduces to a
scalar NLL, and is rematerialized in backward (``jax.checkpoint``), so peak
memory is one (B, chunk, V_shard) tile. The unembedding matmul shards over
(batch=data, vocab=model); the log-sum-exp over the sharded vocab lowers to
one small all-reduce per chunk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import softcap
from repro.sharding.partition import constrain


def _pick_chunk(s: int, pref: int = 512) -> int:
    if s <= pref:
        return s
    for c in range(pref, 0, -1):
        if s % c == 0:
            return c
    return s


def chunked_cross_entropy(hidden: jax.Array, embedding: jax.Array,
                          targets: jax.Array, *, logit_softcap: float = 0.0,
                          chunk: int = 512) -> jax.Array:
    """Mean next-token NLL. hidden: (B, S, D); embedding: (V, D);
    targets: (B, S) int32. Gradients flow to both hidden and embedding."""
    b, s, d = hidden.shape
    chunk = _pick_chunk(s, chunk)
    nc = s // chunk
    xc = hidden.reshape(b, nc, chunk, d).swapaxes(0, 1)     # (nc, B, C, D)
    tc = targets.reshape(b, nc, chunk).swapaxes(0, 1)       # (nc, B, C)

    def body(carry, inp):
        x_i, t_i = inp
        logits = jnp.einsum("bcd,vd->bcv", x_i, embedding,
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, ("batch", None, "vocab"))
        logits = softcap(logits, logit_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)            # (B, C)
        ll = jnp.take_along_axis(logits, t_i[..., None].astype(jnp.int32),
                                 axis=-1)[..., 0]
        return carry + jnp.sum(logz - ll), None

    body = jax.checkpoint(body, prevent_cse=False)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, tc))
    return total / (b * s)
