from repro.train.state import TrainState, init_state, state_specs
from repro.train.loss import chunked_cross_entropy
from repro.train.step import make_train_step, pick_q_chunk

__all__ = [
    "TrainState", "init_state", "state_specs", "chunked_cross_entropy",
    "make_train_step", "pick_q_chunk",
]
