"""Train state: (params, optimizer state, step) as one pytree.

``state_specs`` mirrors the state with logical-axis tuples so the whole
thing — including the f32 AdamW/RMSprop moments — shards with one rules
table. Optimizer moments inherit their parameter's spec (FSDP already
shards every large dim, so the moments land at params_bytes × 4 / n_devices
without a separate ZeRO pass).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim.optimizers import AdamWState, adamw_init, rmsprop_init


class TrainState(NamedTuple):
    params: Any
    opt: Any                      # AdamWState | rmsprop tree
    step: jax.Array
    # Cached FLGW sparse metadata (repro.core.encoder.PlanState) on the
    # grouped path; () otherwise, so non-grouped states keep their exact
    # pre-plans pytree leaves (checkpoints, shardings, donation unchanged).
    plans: Any = ()


def _uses_plans(cfg: ModelConfig) -> bool:
    return cfg.flgw_groups > 1 and cfg.flgw_path == "grouped"


def init_state(key, cfg: ModelConfig, *, optimizer: str = "adamw"
               ) -> TrainState:
    params, _ = transformer.lm_init(key, cfg)
    if optimizer == "adamw":
        opt = adamw_init(params)
    elif optimizer == "rmsprop":
        opt = rmsprop_init(params)
    else:
        raise ValueError(optimizer)
    plans = transformer.encode_plans(params, cfg) if _uses_plans(cfg) else ()
    return TrainState(params=params, opt=opt,
                      step=jnp.zeros((), jnp.int32), plans=plans)


def param_specs(cfg: ModelConfig):
    """Logical spec tree of the params, built without any allocation.

    ``lm_init`` interleaves spec construction with (traced) initialization;
    running it under ``eval_shape`` executes the Python body once — specs
    come out through a closure box, params stay abstract.
    """
    box = {}

    def capture(k):
        p, s = transformer.lm_init(k, cfg)
        box["specs"] = s
        return p

    jax.eval_shape(capture, jax.random.PRNGKey(0))
    return box["specs"]


def plan_specs(cfg: ModelConfig):
    """Logical spec tree of the cached PlanState (replicated: the compact
    metadata is small int/bool tensors consumed whole by every shard)."""
    if not _uses_plans(cfg):
        return ()
    aplans = jax.eval_shape(
        lambda k: transformer.encode_plans(transformer.lm_init(k, cfg)[0],
                                           cfg),
        jax.random.PRNGKey(0))
    return jax.tree.map(lambda a: (None,) * a.ndim, aplans)


def state_specs(cfg: ModelConfig, *, optimizer: str = "adamw"):
    """Logical spec tree with the same structure as ``init_state``'s output."""
    pspecs = param_specs(cfg)
    if optimizer == "adamw":
        opt = AdamWState(mu=pspecs, nu=pspecs, count=())
    else:
        opt = pspecs
    return TrainState(params=pspecs, opt=opt, step=(),
                      plans=plan_specs(cfg))


def abstract_state(cfg: ModelConfig, *, optimizer: str = "adamw"):
    """ShapeDtypeStruct tree of the full train state (no allocation)."""
    return jax.eval_shape(
        lambda k: init_state(k, cfg, optimizer=optimizer),
        jax.random.PRNGKey(0))
