"""Train state: (params, optimizer state, step) as one pytree.

``state_specs`` mirrors the state with logical-axis tuples so the whole
thing — including the f32 AdamW/RMSprop moments — shards with one rules
table. Optimizer moments inherit their parameter's spec (FSDP already
shards every large dim, so the moments land at params_bytes × 4 / n_devices
without a separate ZeRO pass).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim.optimizers import AdamWState, adamw_init, rmsprop_init


class TrainState(NamedTuple):
    params: Any
    opt: Any                      # AdamWState | rmsprop tree
    step: jax.Array
    # Cached FLGW sparse metadata (repro.core.encoder.PlanState) on the
    # grouped path; () otherwise, so non-grouped states keep their exact
    # pre-plans pytree leaves (checkpoints, shardings, donation unchanged).
    plans: Any = ()


def _uses_plans(cfg: ModelConfig) -> bool:
    return cfg.flgw_groups > 1 and cfg.flgw_path == "grouped"


def init_state(key, cfg: ModelConfig, *, optimizer: str = "adamw"
               ) -> TrainState:
    params, _ = transformer.lm_init(key, cfg)
    if optimizer == "adamw":
        opt = adamw_init(params)
    elif optimizer == "rmsprop":
        opt = rmsprop_init(params)
    else:
        raise ValueError(optimizer)
    plans = transformer.encode_plans(params, cfg) if _uses_plans(cfg) else ()
    return TrainState(params=params, opt=opt,
                      step=jnp.zeros((), jnp.int32), plans=plans)


def param_specs(cfg: ModelConfig):
    """Logical spec tree of the params, built without any allocation.

    ``lm_init`` interleaves spec construction with (traced) initialization;
    running it under ``eval_shape`` executes the Python body once — specs
    come out through a closure box, params stay abstract.
    """
    box = {}

    def capture(k):
        p, s = transformer.lm_init(k, cfg)
        box["specs"] = s
        return p

    jax.eval_shape(capture, jax.random.PRNGKey(0))
    return box["specs"]


def plan_specs(cfg: ModelConfig):
    """Logical spec tree of the cached PlanState (replicated: the compact
    metadata is small int/bool tensors consumed whole by every shard).
    Shared with the serving cache — see ``transformer.plan_specs``."""
    return transformer.plan_specs(cfg)


def state_specs(cfg: ModelConfig, *, optimizer: str = "adamw"):
    """Logical spec tree with the same structure as ``init_state``'s output."""
    pspecs = param_specs(cfg)
    if optimizer == "adamw":
        opt = AdamWState(mu=pspecs, nu=pspecs, count=())
    else:
        opt = pspecs
    return TrainState(params=pspecs, opt=opt, step=(),
                      plans=plan_specs(cfg))


def abstract_state(cfg: ModelConfig, *, optimizer: str = "adamw"):
    """ShapeDtypeStruct tree of the full train state (no allocation)."""
    return jax.eval_shape(
        lambda k: init_state(k, cfg, optimizer=optimizer),
        jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Checkpoint restore (plans-aware)
# ---------------------------------------------------------------------------

def reencode_plans(state: TrainState, cfg: ModelConfig) -> TrainState:
    """Fresh plans from the state's own params (no-op off the grouped
    path). Restoring params and then calling this makes a restore
    invariant to the refresh mode and to whatever plans (stale, absent,
    or pre-plans-era) the checkpoint carried."""
    if not _uses_plans(cfg):
        return state
    return state._replace(plans=transformer.encode_plans(state.params, cfg))


def restore_state(ckpt_dir, state: TrainState, cfg: ModelConfig, *,
                  shardings=None, step=None) -> tuple[TrainState, int]:
    """Restore a :class:`TrainState`, re-encoding plans from the restored
    params instead of loading them.

    Two bugs this kills at once: (1) pre-plans grouped manifests have no
    ``plans`` leaves, so a naive full-tree restore raises — dropping the
    plans from the restore *target* migrates those checkpoints for free;
    (2) even plans-era checkpoints hold the plans that were current at
    save time, which may be stale relative to the refresh policy — the
    post-restore re-encode makes the first resumed step bitwise-identical
    to an uninterrupted run under any refresh mode.
    """
    from repro import checkpoint as ckpt
    target = state._replace(plans=())
    if shardings is not None and hasattr(shardings, "_replace"):
        shardings = shardings._replace(plans=())
    restored, s = ckpt.restore_checkpoint(ckpt_dir, target,
                                          shardings=shardings, step=step)
    return reencode_plans(restored, cfg), s
