"""train_step factory — the function the launcher jits.

``make_train_step(cfg)`` returns a pure ``(state, batch) -> (state, metrics)``
step: forward (remat-scanned blocks, chunked CE), backward, optional
microbatch gradient accumulation (scan), global-norm clip, optimizer update.

The serving factories that used to live here (``make_serve_step`` /
``make_prefill_step``) moved to ``repro.serving.steps`` behind the unified
:class:`repro.serving.ServeSession` API; the deprecation shims that bridged
the move are gone — use ``repro.serving.make_decode_step`` /
``repro.serving.make_prefill_step`` (old ``make_serve_step(...,
refresh_plans=True)`` maps to ``make_decode_step(...,
certify_each_step=True)``).

Everything is shape-static: the dry-run lowers these exact functions against
ShapeDtypeStructs, and the real launcher jits them with the same shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import encoder as planenc
from repro.core.flgw import FLGWConfig
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim.optimizers import adamw, clip_by_global_norm, rmsprop
from repro.train.loss import chunked_cross_entropy
from repro.train.state import TrainState


def pick_q_chunk(s: int, pref: int = 512) -> int:
    """Largest divisor of ``s`` that is ≤ pref and a multiple of 128 (or s)."""
    if s <= pref:
        return s
    for c in range(pref, 127, -128):
        if s % c == 0:
            return c
    for c in range(pref, 0, -1):
        if s % c == 0:
            return c
    return s


def _loss_fn(params, batch, cfg: ModelConfig, q_chunk: int, banded: bool,
             ce_chunk: int = 512, ssd_unroll: bool = False,
             unroll_blocks: bool = False, attn_identity: bool = False,
             plans=None):
    hidden, aux, _ = transformer.lm_apply(
        params, cfg, batch["tokens"], batch["positions"],
        patch_embeds=batch.get("patch_embeds"),
        frames=batch.get("frames"),
        q_chunk=q_chunk, banded=banded, return_hidden=True,
        ssd_unroll=ssd_unroll, unroll_blocks=unroll_blocks,
        attn_identity=attn_identity, plans=plans)
    ce = chunked_cross_entropy(
        hidden, params["embed"]["embedding"], batch["targets"],
        logit_softcap=cfg.logit_softcap, chunk=ce_chunk)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, *, optimizer: str = "adamw",
                    lr: float = 3e-4, clip: float = 1.0,
                    microbatches: int = 1, banded: bool = False,
                    q_chunk: Optional[int] = None, ce_chunk: int = 512,
                    ssd_unroll: bool = False, unroll_blocks: bool = False,
                    attn_identity: bool = False, schedule=None):
    """Returns ``train_step(state, batch) -> (state, metrics)``.

    ``q_chunk`` / ``ce_chunk`` / ``ssd_unroll`` exist for the dry-run cost
    variant (scan-free lowering so HLO cost analysis sees every op); the
    real launcher uses the memory-bounded defaults.

    On the FLGW grouped path the step drives the same plan-refresh logic
    as the MARL engine: ``state.plans`` (the cached PlanState built at
    ``init_state``) passes through ``encoder.maybe_refresh`` against the
    ``schedule``'s refresh mode before the forward, so every projection
    consumes cached metadata instead of re-encoding per call, and the
    (possibly re-encoded) plans carry into the next state.
    """
    uses_plans = cfg.flgw_groups > 1 and cfg.flgw_path == "grouped"
    fl_cfg = FLGWConfig(groups=cfg.flgw_groups, path=cfg.flgw_path)

    def train_step(state: TrainState, batch):
        s = batch["tokens"].shape[1]
        qc = q_chunk or pick_q_chunk(s)
        plans = state.plans
        if uses_plans and isinstance(plans, planenc.PlanState):
            plans = planenc.maybe_refresh(state.params, plans, state.step,
                                          fl_cfg, schedule)
        grad_fn = jax.value_and_grad(
            functools.partial(_loss_fn, cfg=cfg, q_chunk=qc, banded=banded,
                              ce_chunk=ce_chunk, ssd_unroll=ssd_unroll,
                              unroll_blocks=unroll_blocks,
                              attn_identity=attn_identity,
                              plans=plans if uses_plans else None),
            has_aux=True)

        if microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc(carry, b_i):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(state.params, b_i)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            (grads, loss), _ = jax.lax.scan(
                acc, (zeros, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        grads, gnorm = clip_by_global_norm(grads, clip)
        if optimizer == "adamw":
            params, opt = adamw(state.params, grads, state.opt, lr=lr)
        else:
            params, opt = rmsprop(state.params, grads, state.opt, lr=lr)
        new_state = TrainState(params=params, opt=opt, step=state.step + 1,
                               plans=plans)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_state, metrics

    return train_step
