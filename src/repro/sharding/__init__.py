from repro.sharding.partition import (  # noqa: F401
    LOGICAL_RULES, logical_to_pspec, shardings_for, batch_pspec,
    batch_sharding, param_shardings, activation_rules,
)
