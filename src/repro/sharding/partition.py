"""Logical-axis partitioning: spec trees -> NamedSharding.

Every initializer in the framework returns ``(params, specs)`` where
``specs`` mirrors the param tree with tuples of *logical* axis names
(``"embed"``, ``"ffn"``, ``"heads"``, ``"vocab"``, ``"expert"``, ...).
This module maps those logical names onto *mesh* axes
(``"pod"``, ``"data"``, ``"model"``) via a rules table — the standard
MaxText/Flax-style indirection that lets one model definition serve any
mesh topology.

Default rules implement the DESIGN.md §4 layout:

* tensor parallelism (``model`` axis): attention heads, FFN hidden dim,
  vocab/embedding rows, MoE experts, FLGW group-capacity tiles;
* data parallelism (``data`` + ``pod`` axes): the batch dimension of all
  activations;
* everything else replicated.

A name mapped to a mesh axis is silently dropped (replicated) when the
axis does not exist in the current mesh — the same config therefore runs
on 1-device CPU, a single pod (data, model), or multi-pod (pod, data,
model) without edits. Rules also drop a mesh axis that was already used
earlier in the same spec (an axis may shard at most one dim of a tensor).
"""
from __future__ import annotations

from typing import Any, Mapping, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical name -> mesh axis (or tuple of mesh axes, or None = replicate).
#
# Weight layout is FSDP(data) × TP(model): every projection shards its
# hidden dim over "model" (intra-layer parallelism — the paper's multi-core
# split) *and* its d_model dim over "data" (fully-sharded data parallel).
# GSPMD turns the data-dim sharding into per-layer weight all-gathers in
# forward/backward plus reduce-scatter of grads — the ZeRO-3 schedule —
# which is what lets arctic-480b (960 GB bf16) and jamba-398b fit 16 GB/chip
# meshes. The "pod" axis stays pure DP: weights replicate across pods, only
# gradients cross pod boundaries (optionally compressed, repro.optim).
LOGICAL_RULES: dict[str, Any] = {
    # --- weights -----------------------------------------------------------
    "embed": "data",          # d_model dim: FSDP shard
    "ffn": "model",           # FFN hidden dim — intra-layer parallelism
    "heads": "model",         # attention heads
    "kv_heads": "model",      # GQA KV heads (fewer than heads; may not divide)
    "vocab": "model",         # embedding / unembedding rows
    "expert": None,           # MoE expert axis: inner dims carry the sharding
    "layers": None,           # scan axis: always replicated
    # FLGW grouping matrices follow their weight's sharded dim via the axes
    # recorded at dense_init time; the group dim itself is replicated.
    "groups": None,
    # FLGW compact tiles: the capN (output) dim carries the intra-layer
    # parallelism — the paper's multi-core split of the compact rows.
    "flgw_cap": "model",
    # --- activations -------------------------------------------------------
    "batch": ("pod", "data"),  # global batch over all data-parallel axes
    "seq": None,               # sequence: local (no SP by default)
    "seq_sp": "model",         # sequence parallelism opt-in (perf path)
    "seq_kv": "model",         # decode KV caches: shard the KV sequence dim
    # --- ic3net (tiny, replicated) ------------------------------------------
    "in": None, "out": None, "hidden": None, "gates": None,
    # --- marl mesh (repro.launch.mesh.make_marl_mesh) -----------------------
    # Rollout batch over parallel environments and per-agent activations
    # over the agent axis. These mesh axes only exist on the MARL mesh;
    # on the production (data, model) mesh the names drop to replication,
    # so the constraints in marl/train and marl/ic3net are inert there.
    "env": "env",
    "agent": "agent",
}


def _axes_of(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def logical_to_pspec(spec: Sequence[Optional[str]], mesh: Mesh,
                     rules: Optional[Mapping[str, Any]] = None) -> P:
    """One logical spec tuple -> PartitionSpec valid on ``mesh``."""
    rules = LOGICAL_RULES if rules is None else rules
    used: set[str] = set()
    out = []
    for name in spec:
        axis = rules.get(name) if name is not None else None
        if axis is None:
            out.append(None)
            continue
        cand = axis if isinstance(axis, tuple) else (axis,)
        keep = tuple(a for a in cand
                     if a in _axes_of(mesh) and a not in used)
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(keep)
    # trim trailing Nones for a tidy spec
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _is_spec(x) -> bool:
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def shardings_for(specs, mesh: Mesh,
                  rules: Optional[Mapping[str, Any]] = None):
    """Spec tree -> NamedSharding tree (same structure)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, logical_to_pspec(s, mesh, rules)),
        specs, is_leaf=_is_spec)


def param_shardings(specs, mesh: Mesh,
                    rules: Optional[Mapping[str, Any]] = None):
    """Alias of shardings_for — named for call-site clarity."""
    return shardings_for(specs, mesh, rules)


def constrained_pspec(spec: Sequence[Optional[str]], shape,
                      mesh: Mesh,
                      rules: Optional[Mapping[str, Any]] = None) -> P:
    """Shape-aware spec resolution: drop mesh axes that don't divide the dim.

    GQA KV head counts (4–16), batch=1 long-context cells and 8-expert MoE
    all hit non-divisible dims on a 16-wide axis; dropping (replicating)
    beats uneven GSPMD padding for predictable memory accounting.
    """
    rules = LOGICAL_RULES if rules is None else rules
    used: set[str] = set()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, name in enumerate(spec):
        dim = shape[i] if i < len(shape) else 1
        axis = rules.get(name) if name is not None else None
        if axis is None:
            out.append(None)
            continue
        cand = axis if isinstance(axis, tuple) else (axis,)
        keep = []
        for a in cand:
            if a in sizes and a not in used and dim % sizes[a] == 0:
                keep.append(a)
                dim //= sizes[a]
        used.update(keep)
        out.append(None if not keep
                   else keep[0] if len(keep) == 1 else tuple(keep))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def constrained_shardings(specs, shaped, mesh: Mesh,
                          rules: Optional[Mapping[str, Any]] = None):
    """(spec tree, ShapeDtypeStruct tree) -> NamedSharding tree.

    The dry-run path: shapes come from ``jax.eval_shape`` so nothing is
    allocated while resolving divisibility.
    """
    return jax.tree.map(
        lambda s, a: NamedSharding(
            mesh, constrained_pspec(s, a.shape, mesh, rules)),
        specs, shaped, is_leaf=_is_spec)


def batch_pspec(mesh: Mesh, ndim: int = 2,
                rules: Optional[Mapping[str, Any]] = None) -> P:
    """(batch, seq, ...) activation spec: batch over all data axes."""
    spec = ["batch"] + [None] * (ndim - 1)
    return logical_to_pspec(spec, mesh, rules)


def batch_sharding(mesh: Mesh, ndim: int = 2,
                   rules: Optional[Mapping[str, Any]] = None) -> NamedSharding:
    return NamedSharding(mesh, batch_pspec(mesh, ndim, rules))


def activation_rules(mesh: Mesh) -> dict[str, Any]:
    """Rules dict resolved against a given mesh (for introspection/tests)."""
    return {k: logical_to_pspec((k,), mesh) for k in LOGICAL_RULES}


# ---------------------------------------------------------------------------
# Activation sharding constraints
#
# Without explicit constraints GSPMD propagates the FSDP weight sharding
# into the activations (feature-dim sharded, batch replicated!) — measured
# on the gemma2-2b dry-run as hundreds of full-batch activation reshards.
# The launcher opts in via ``use_constraints(mesh)``; tests and single-
# device runs never enter the context, so the model code stays mesh-free.
# ---------------------------------------------------------------------------

import contextlib as _contextlib

_CONSTRAINT_MESH: list = []


@_contextlib.contextmanager
def use_constraints(mesh: Mesh):
    """Enable logical activation constraints for lowering under ``mesh``."""
    _CONSTRAINT_MESH.append(mesh)
    try:
        yield
    finally:
        _CONSTRAINT_MESH.pop()


def constrain(x, spec: Sequence[Optional[str]],
              rules: Optional[Mapping[str, Any]] = None):
    """``with_sharding_constraint(x, logical spec)`` if a constraint mesh is
    active; no-op otherwise. Mesh axes that do not divide the dim drop."""
    if not _CONSTRAINT_MESH:
        return x
    mesh = _CONSTRAINT_MESH[-1]
    pspec = constrained_pspec(spec, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))
