"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and smoke tests must keep seeing 1 device.

Topology: a TPU v5e pod is a 16×16 chip grid; the single-pod mesh maps it
as (data=16, model=16) so the model axis stays inside the pod's dense ICI.
Multi-pod adds a leading "pod" axis over the (slower) inter-pod links —
only data-parallel gradient traffic crosses it (DESIGN.md §4).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_from_devices(devices=None, *, model: int = 0) -> Mesh:
    """Elastic mesh: build (data, model) from whatever devices are alive.

    Used by runtime/elastic.py after a failure shrinks the device set and by
    single-host tests (1 device -> (1, 1) mesh). ``model`` forces the model-
    axis width; default picks the largest power-of-two ≤ 16 that divides
    the device count.
    """
    devices = jax.devices() if devices is None else devices
    n = len(devices)
    if model <= 0:
        model = 1
        while model < 16 and n % (model * 2) == 0:
            model *= 2
    assert n % model == 0, (n, model)
    import numpy as np
    arr = np.array(devices).reshape(n // model, model)
    return Mesh(arr, ("data", "model"))
