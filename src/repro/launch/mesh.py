"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and smoke tests must keep seeing 1 device.

Topology: a TPU v5e pod is a 16×16 chip grid; the single-pod mesh maps it
as (data=16, model=16) so the model axis stays inside the pod's dense ICI.
Multi-pod adds a leading "pod" axis over the (slower) inter-pod links —
only data-parallel gradient traffic crosses it (DESIGN.md §4).
"""
from __future__ import annotations

import os
import warnings

import jax
from jax.sharding import Mesh

_DISTRIBUTED = {"initialized": False}


def init_distributed(coordinator: str | None = None,
                     num_processes: int | None = None,
                     process_id: int | None = None, *,
                     strict: bool = False) -> dict:
    """Bring up ``jax.distributed`` for a multi-host mesh, with a fallback.

    The PR-5 mesh engine is single-host: ``make_marl_mesh`` reshapes
    ``jax.devices()``, which only sees other hosts' devices after
    ``jax.distributed.initialize``. This helper owns that bring-up:

    * arguments default to the standard env vars (``JAX_COORDINATOR`` /
      ``COORDINATOR_ADDRESS``, ``JAX_NUM_PROCESSES``, ``JAX_PROCESS_ID``)
      so launchers can configure it without code changes;
    * idempotent — a second call returns the recorded topology instead of
      re-initializing (jax raises otherwise);
    * non-strict (default): any bring-up failure degrades to single-process
      with a warning, so the same entry point runs on a laptop and a pod
      (``strict=True`` re-raises — CI's 2-process smoke uses it to make a
      botched rendezvous a failure instead of two silent singletons).

    Returns ``{"distributed", "process_index", "process_count",
    "local_devices", "global_devices"}``. Cross-process *collectives* are a
    backend property (the CPU backend does not implement them); what this
    enables everywhere is the global device view plus per-host data
    feeding via :func:`host_local_batch`.
    """
    coordinator = coordinator or os.environ.get(
        "JAX_COORDINATOR") or os.environ.get("COORDINATOR_ADDRESS")
    if num_processes is None and os.environ.get("JAX_NUM_PROCESSES"):
        num_processes = int(os.environ["JAX_NUM_PROCESSES"])
    if process_id is None and os.environ.get("JAX_PROCESS_ID"):
        process_id = int(os.environ["JAX_PROCESS_ID"])

    if not _DISTRIBUTED["initialized"] and coordinator \
            and num_processes and num_processes > 1:
        try:
            jax.distributed.initialize(
                coordinator_address=coordinator,
                num_processes=num_processes, process_id=process_id)
            _DISTRIBUTED["initialized"] = True
        except Exception as e:                      # noqa: BLE001
            if strict:
                raise
            warnings.warn(
                f"jax.distributed bring-up failed ({e!r}); continuing "
                "single-process", RuntimeWarning, stacklevel=2)
    return {
        "distributed": _DISTRIBUTED["initialized"],
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
        "local_devices": jax.local_device_count(),
        "global_devices": jax.device_count(),
    }


def host_local_batch(global_batch: int) -> tuple[int, int]:
    """Per-host slice of a global env batch: ``(local_batch, offset)``.

    Multi-host data feeding: each process rolls out only its shard of the
    global batch — process ``i`` owns rows ``[offset, offset + local)`` —
    and the learner's mesh program addresses the batch globally. The
    global batch must divide evenly (ragged shards would make per-host
    array shapes disagree, which ``jax.make_array_from_process_local_data``
    rejects anyway).
    """
    n = jax.process_count()
    if global_batch % n:
        raise ValueError(
            f"global batch {global_batch} does not divide over {n} "
            "processes; pick a multiple")
    local = global_batch // n
    return local, jax.process_index() * local


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def parse_marl_mesh(spec: str) -> tuple:
    """``"ENV,AGENT"`` CLI spec -> (env, agent) shard counts.

    Raises ``ValueError`` with a usage-style message on anything that is
    not exactly two comma-separated ints — shared by every CLI that
    exposes a ``--mesh`` flag, so malformed specs become argparse errors
    instead of index/unpack tracebacks.
    """
    parts = spec.split(",")
    try:
        shape = tuple(int(x) for x in parts)
    except ValueError:
        shape = ()
    if len(shape) != 2:
        raise ValueError(
            f"--mesh expects ENV,AGENT (two comma-separated ints, e.g. "
            f"2,2), got {spec!r}")
    return shape


def make_marl_mesh(*, env: int = 0, agent: int = 1, devices=None) -> Mesh:
    """2-D ``("env", "agent")`` mesh for the MARL training engine.

    ``env`` shards the rollout batch (data parallelism over parallel
    environments — the axis that dominates MARL wall-clock); ``agent``
    shards the per-agent activation axis inside each environment (the
    paper's multi-core split of per-agent work). IC3Net weights are
    agent-shared, so the learner state replicates; only rollout work
    partitions. ``env <= 0`` takes every device left after the agent
    axis. A ``(1, 1)`` mesh works on any host — the single-device parity
    configuration the tests pin against the host loop.
    """
    devices = jax.devices() if devices is None else list(devices)
    n = len(devices)
    agent = max(agent, 1)
    if env <= 0:
        if n % agent:
            raise ValueError(
                f"agent axis width {agent} does not divide {n} devices")
        env = n // agent
    if env * agent > n:
        raise ValueError(f"marl mesh ({env}, {agent}) needs "
                         f"{env * agent} devices, only {n} available")
    import numpy as np
    arr = np.array(devices[:env * agent]).reshape(env, agent)
    return Mesh(arr, ("env", "agent"))


def describe_marl_mesh(mesh: Mesh, *, batch: int, n_agents: int) -> str:
    """Dry-run-style spec of what shards where on a MARL mesh.

    Mirrors ``launch/dryrun.py``'s cell printing for the MARL engine: one
    line per mesh axis with the dimension it partitions and the resulting
    per-shard workload (axes that do not divide their dimension drop to
    replication — the same shape-aware rule ``sharding.partition``
    applies when lowering).
    """
    e, a = mesh.shape["env"], mesh.shape["agent"]

    def per(total: int, width: int, what: str) -> str:
        if total % width == 0:
            return f"{total // width} {what}/shard"
        return f"replicated ({total} % {width} != 0)"

    return "\n".join([
        f"marl mesh ({e}x{a}): axes (env, agent) over {e * a} device(s)",
        f"  env   [{e}]: rollout batch {batch:>4} -> "
        f"{per(batch, e, 'envs')}",
        f"  agent [{a}]: agent axis    {n_agents:>4} -> "
        f"{per(n_agents, a, 'agents')}",
        "  learner state (params/opt/plans): replicated "
        "(IC3Net weights are agent-shared)",
    ])


def make_mesh_from_devices(devices=None, *, model: int = 0) -> Mesh:
    """Elastic mesh: build (data, model) from whatever devices are alive.

    Used by runtime/elastic.py after a failure shrinks the device set and by
    single-host tests (1 device -> (1, 1) mesh). ``model`` forces the model-
    axis width; default picks the largest power-of-two ≤ 16 that divides
    the device count.
    """
    devices = jax.devices() if devices is None else devices
    n = len(devices)
    if model <= 0:
        model = 1
        while model < 16 and n % (model * 2) == 0:
            model *= 2
    assert n % model == 0, (n, model)
    import numpy as np
    arr = np.array(devices).reshape(n // model, model)
    return Mesh(arr, ("data", "model"))
