"""LM training launcher: mesh + sharded init + data + fault-tolerant loop.

The production entry point (and the end-to-end driver the examples call):

  PYTHONPATH=src python -m repro.launch.train --arch gemma2_2b --smoke \
      --steps 50 --batch 8 --seq 256 --flgw-groups 4

On the CPU container this runs the reduced (smoke) configs; on a real
fleet the same file runs the full config on the production mesh — the only
difference is ``--smoke`` and the device set jax reports.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.schedule import SparsitySchedule
from repro.data.pipeline import SyntheticTokens, make_batch_iterator
from repro.launch.mesh import make_mesh_from_devices
from repro.runtime.fault import PreemptionGuard, StepRunner
from repro.sharding import partition
from repro.train import state as state_lib
from repro.train import step as step_lib


def train_lm(arch: str, *, smoke: bool = True, steps: int = 20,
             batch: int = 8, seq: int = 256, lr: float = 3e-4,
             flgw_groups: int = 1, flgw_path: str = "masked",
             refresh_every: int = 1, refresh: str = "period",
             optimizer: str = "adamw", ckpt_dir: str = None,
             save_every: int = 100, log_every: int = 10,
             banded: bool = False, seed: int = 0):
    get = registry.get_smoke_config if smoke else registry.get_config
    overrides = {}
    if flgw_groups > 1:
        overrides = dict(flgw_groups=flgw_groups, flgw_path=flgw_path)
    cfg = get(arch, **overrides)
    # plan-refresh schedule for the grouped path (the decoder stack shares
    # the MARL engine's encoder subsystem; see repro.core.encoder)
    schedule = None
    if flgw_groups > 1 and flgw_path == "grouped" and \
            (refresh_every > 1 or refresh != "period"):
        schedule = SparsitySchedule(groups=flgw_groups,
                                    refresh_every=refresh_every,
                                    refresh=refresh)

    mesh = make_mesh_from_devices()
    specs = state_lib.state_specs(cfg, optimizer=optimizer)
    abstract = state_lib.abstract_state(cfg, optimizer=optimizer)
    state_sh = partition.constrained_shardings(specs, abstract, mesh)
    batch_sh = {k: partition.batch_sharding(mesh, 2)
                for k in ("tokens", "targets", "positions")}

    with mesh, partition.use_constraints(mesh):
        init = jax.jit(
            lambda k: state_lib.init_state(k, cfg, optimizer=optimizer),
            out_shardings=state_sh)
        state = init(jax.random.PRNGKey(seed))

        step_fn = jax.jit(
            step_lib.make_train_step(cfg, optimizer=optimizer, lr=lr,
                                     banded=banded, schedule=schedule),
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None), donate_argnums=(0,))

        ds = SyntheticTokens(cfg.vocab, batch, seq, seed=seed)
        runner = None
        start = 0
        if ckpt_dir:
            runner = StepRunner(step_fn, ckpt_dir, save_every=save_every)
            # Plans-aware restore: migrates pre-plans grouped manifests and
            # re-encodes TrainState.plans from the restored params, so the
            # resumed step is bitwise-identical under any refresh mode.
            state, start = runner.restore_or(
                state, shardings=state_sh,
                restore_fn=lambda s, sh: state_lib.restore_state(
                    ckpt_dir, s, cfg, shardings=sh))
        batches = make_batch_iterator(ds, start_step=start,
                                      sharding=batch_sh)

        t0 = time.time()
        if runner is not None:
            state, end, history = runner.run(
                state, batches, start_step=start, max_steps=steps,
                log_every=log_every)
        else:
            history = []
            end = start
            for b in batches:
                if end >= steps:
                    break
                state, metrics = step_fn(state, b)
                end += 1
                history.append(metrics)
                if log_every and end % log_every == 0:
                    print(f"step {end}: loss="
                          f"{float(metrics['loss']):.4f}", flush=True)  # noqa: ANL002 — log_every-gated print; fetch is the point
        dt = time.time() - t0

    losses = [float(h["loss"]) for h in history]
    print(f"{arch}: steps {start}->{end} in {dt:.1f}s  "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}"
          if losses else f"{arch}: no steps run")
    return state, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=[a for a in registry.ARCH_IDS if a != "ic3net"])
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--flgw-groups", type=int, default=1)
    ap.add_argument("--flgw-path", default="masked",
                    choices=("masked", "grouped"))
    ap.add_argument("--refresh", type=int, default=1,
                    help="re-encode the grouped path's plan cache every k "
                         "steps (OSEL amortization; 1 = every step)")
    ap.add_argument("--refresh-mode", default="period",
                    choices=("period", "on_change", "hybrid"),
                    help="plan-refresh policy (see repro.core.encoder)")
    ap.add_argument("--optimizer", default="adamw",
                    choices=("adamw", "rmsprop"))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--banded", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args(argv)
    train_lm(a.arch, smoke=a.smoke, steps=a.steps, batch=a.batch,
             seq=a.seq, lr=a.lr, flgw_groups=a.flgw_groups,
             flgw_path=a.flgw_path, refresh_every=a.refresh,
             refresh=a.refresh_mode, optimizer=a.optimizer,
             ckpt_dir=a.ckpt_dir, save_every=a.save_every,
             log_every=a.log_every, banded=a.banded, seed=a.seed)


if __name__ == "__main__":
    main()
