"""Launch-scale tooling: meshes, dry-runs, roofline models."""
