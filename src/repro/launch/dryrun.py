import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: the jit'd
train/serve/prefill step is lowered against ShapeDtypeStruct stand-ins
(nothing allocated) and compiled for the production mesh.

Two compiled artifacts per cell:

1. The REAL program (scanned blocks, chunked attention/CE) — proves the
   sharding compiles and yields memory_analysis() (fits per device?).
2. COST VARIANTS — HLO cost analysis counts a while-loop body once
   regardless of trip count, so flops/bytes/collective bytes from the
   scanned program are useless. The cost variant removes every inner scan
   (q_chunk=∞ single-chunk attention, unchunked CE, Python-unrolled SSD —
   all FLOP-identical) and is compiled at n_blocks ∈ {1, 2}; per-block cost
   is the difference, totals extrapolate linearly: exact for the linear
   block structure. Whisper adds an encoder_layers ∈ {1, 2} axis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2_2b \
      --shape train_4k [--multi-pod] [--flgw-groups 4 --flgw-path masked]
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes
"""

import argparse
import json
import pathlib
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.sharding import partition
from repro.train import state as state_lib
from repro.serving import steps as serving_steps
from repro.train import step as step_lib

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / \
    "benchmarks" / "results" / "dryrun"

_NO_CHUNK = 1 << 30


def _batch_specs(cfg, shape_name: str):
    """Logical specs for the input batch dict of one cell."""
    specs = {}
    for name, sds in registry.input_specs(cfg, shape_name).items():
        specs[name] = ("batch",) + (None,) * (len(sds.shape) - 1)
    return specs


def _make_cfg(arch: str, *, flgw_groups=1, flgw_path="masked",
              n_blocks=None, encoder_layers=None, extra=None):
    overrides = dict(extra or {})
    if flgw_groups > 1:
        overrides.update(flgw_groups=flgw_groups, flgw_path=flgw_path)
    base = registry.get_config(arch)
    if n_blocks is not None:
        overrides["n_layers"] = n_blocks * base.period
    if encoder_layers is not None and base.encoder_layers:
        overrides["encoder_layers"] = encoder_layers
    return base.with_updates(**overrides) if overrides else base


def build_cell(cfg, shape_name: str, mesh, *, banded: bool = False,
               optimizer: str = "adamw", cost_mode: bool = False,
               attn_identity: bool = False, rules=None):
    """Returns (jitted_fn, abstract_args) for one cell, ready to lower."""
    seq, batch, kind = registry.SHAPES[shape_name]
    inputs = registry.input_specs(cfg, shape_name)
    in_batch_shardings = partition.constrained_shardings(
        _batch_specs(cfg, shape_name), inputs, mesh, rules)
    chunk_kw = (dict(q_chunk=_NO_CHUNK, ssd_unroll=True, unroll_blocks=True)
                if cost_mode else {})
    if attn_identity:
        chunk_kw["attn_identity"] = True

    if kind == "train":
        abstract = state_lib.abstract_state(cfg, optimizer=optimizer)
        specs = state_lib.state_specs(cfg, optimizer=optimizer)
        state_sh = partition.constrained_shardings(specs, abstract, mesh,
                                                   rules)
        fn = step_lib.make_train_step(
            cfg, optimizer=optimizer, banded=banded,
            ce_chunk=_NO_CHUNK if cost_mode else 512, **chunk_kw)
        jf = jax.jit(fn, in_shardings=(state_sh, in_batch_shardings),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
        return jf, (abstract, inputs)

    # serving paths share the param layout with training (no opt state)
    pspecs = state_lib.param_specs(cfg)
    aparams = jax.eval_shape(
        lambda k: transformer.lm_init(k, cfg)[0], jax.random.PRNGKey(0))
    param_sh = partition.constrained_shardings(pspecs, aparams, mesh, rules)

    if kind == "prefill":
        fn = serving_steps.make_prefill_step(cfg, banded=banded, **chunk_kw)
        jf = jax.jit(fn, in_shardings=(param_sh, in_batch_shardings))
        return jf, (aparams, inputs)

    # decode: one new token against a seq-length cache. The cache carries
    # the serving PlanState beside the KV/SSM buffers on the grouped path
    # (init_cache(params=...)), so the compiled decode program runs the
    # flgw_matmul kernel against amortized metadata — no per-step encode.
    acache = jax.eval_shape(
        lambda p: transformer.init_cache(cfg, batch, seq, params=p),
        aparams)
    cache_sh = partition.constrained_shardings(
        transformer.cache_specs(cfg), acache, mesh, rules)
    fn = serving_steps.make_decode_step(cfg, banded=banded,
                                        unroll_blocks=cost_mode)
    tok_sh = in_batch_shardings["tokens"]
    jf = jax.jit(fn, in_shardings=(param_sh, cache_sh, tok_sh, tok_sh),
                 out_shardings=(None, cache_sh), donate_argnums=(1,))
    args = (aparams, acache, inputs["tokens"], inputs["positions"])
    return jf, args


def _compile(cfg, shape_name, mesh, *, banded=False, cost_mode=False,
             attn_identity=False, rules=None, optimizer="adamw"):
    jf, args = build_cell(cfg, shape_name, mesh, banded=banded,
                          cost_mode=cost_mode, attn_identity=attn_identity,
                          rules=rules, optimizer=optimizer)
    from repro.kernels.flgw_matmul import ops as _fops
    with mesh, partition.use_constraints(mesh), _fops.use_reference_impl():
        lowered = jf.lower(*args)
        compiled = lowered.compile()
    return compiled


def _metrics(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = roofline.collective_bytes_from_hlo(hlo)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "fused_bytes": roofline.fused_bytes_from_hlo(hlo),
            "coll": coll}


def _lin(m1: dict, m2: dict, n: int) -> dict:
    """Extrapolate metrics linearly in block count: m(n) = m1 + (n-1)·Δ."""
    def ext(a, b):
        return a + (n - 1) * max(0.0, b - a)
    out = {"flops": ext(m1["flops"], m2["flops"]),
           "bytes": ext(m1["bytes"], m2["bytes"]),
           "fused_bytes": ext(m1["fused_bytes"], m2["fused_bytes"]),
           "coll": {k: ext(m1["coll"][k], m2["coll"][k])
                    for k in m1["coll"]}}
    return out


def extrapolated_cost(arch, shape_name, mesh, *, flgw_groups=1,
                      flgw_path="masked", banded=False,
                      attn_identity=False, rules=None, extra=None,
                      optimizer="adamw") -> dict:
    """flops / bytes / collective bytes of the full-depth cell, from
    scan-free cost variants at n_blocks ∈ {1, 2} (+ encoder axis)."""
    base = registry.get_config(arch)
    nb = base.n_blocks
    kw = dict(banded=banded, cost_mode=True, attn_identity=attn_identity,
              rules=rules, optimizer=optimizer)
    mk = lambda b, e=None: _make_cfg(arch, flgw_groups=flgw_groups,
                                     flgw_path=flgw_path, n_blocks=b,
                                     encoder_layers=e, extra=extra)
    if base.encoder_layers:
        m11 = _metrics(_compile(mk(1, 1), shape_name, mesh, **kw))
        m21 = _metrics(_compile(mk(2, 1), shape_name, mesh, **kw))
        m12 = _metrics(_compile(mk(1, 2), shape_name, mesh, **kw))
        dec = _lin(m11, m21, nb)                       # decoder depth
        ne = base.encoder_layers
        out = {k: dec[k] + (ne - 1) * max(0.0, m12[k] - m11[k])
               for k in ("flops", "bytes", "fused_bytes")}
        out["coll"] = {k: dec["coll"][k] + (ne - 1) *
                       max(0.0, m12["coll"][k] - m11["coll"][k])
                       for k in dec["coll"]}
        return out
    m1 = _metrics(_compile(mk(1), shape_name, mesh, **kw))
    m2 = _metrics(_compile(mk(2), shape_name, mesh, **kw))
    return _lin(m1, m2, nb)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             flgw_groups: int = 1, flgw_path: str = "masked",
             banded: bool = False, flash: bool = False, save: bool = True,
             tag: str = "", with_cost: bool = True, rules=None,
             extra=None, optimizer: str = "adamw",
             proof: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    seq, batch, kind = registry.SHAPES[shape_name]
    cfg = _make_cfg(arch, flgw_groups=flgw_groups, flgw_path=flgw_path,
                    extra=extra)

    # 1. The real program: proves lower+compile, yields memory analysis.
    # (--flash cells compile-prove with the chunked core: identical
    # operands/shardings; the fused kernel is accounted analytically below
    # and validated against the oracle in interpret mode by the tests.)
    t0 = time.time()
    if proof:
        compiled = _compile(cfg, shape_name, mesh, banded=banded,
                            rules=rules, optimizer=optimizer)
    t_compile = time.time() - t0
    mem_info = {}
    if proof:
        try:
            mem = compiled.memory_analysis()
            mem_info = {
                "argument_bytes":
                    int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "generated_code_bytes":
                    int(getattr(mem, "generated_code_size_in_bytes", 0)),
            }
        except Exception as e:  # backend may not implement it
            mem_info = {"error": str(e)}

    result = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "flgw_groups": flgw_groups,
        "flgw_path": flgw_path if flgw_groups > 1 else "dense",
        "banded": banded, "flash": flash,
        "seq": seq, "batch": batch,
        "compile_s": round(t_compile, 2),
        "memory": mem_info,
    }

    # 2. Cost variants (single-pod roofline only).
    if with_cost:
        t1 = time.time()
        cost = extrapolated_cost(arch, shape_name, mesh,
                                 flgw_groups=flgw_groups,
                                 flgw_path=flgw_path, banded=banded,
                                 attn_identity=flash, rules=rules,
                                 extra=extra, optimizer=optimizer)
        if flash and kind in ("train", "prefill"):
            fc = roofline.flash_attention_cost(cfg, batch=batch, seq=seq,
                                               kind=kind)
            cost["flops"] += fc["flops"] / chips
            cost["bytes"] += fc["bytes"] / chips
            cost["fused_bytes"] += fc["bytes"] / chips
            cost["flash_analytic"] = fc
        n_tokens = batch * seq if kind != "decode" else batch
        mf = roofline.model_flops(
            cfg, n_tokens, kind="train" if kind == "train" else "serve")
        if flgw_groups > 1 and flgw_path == "grouped":
            mf = mf / flgw_groups      # compact path: useful FLOPs ÷ G
        terms = roofline.roofline_terms(
            flops_per_chip=cost["flops"], bytes_per_chip=cost["bytes"],
            collective_bytes_per_chip=cost["coll"]["total"] / chips,
            model_flops_total=mf, chips=chips,
            fused_bytes_per_chip=cost["fused_bytes"])
        result.update({
            "tokens": n_tokens,
            "cost": {"flops_per_chip": cost["flops"],
                     "bytes_per_chip": cost["bytes"],
                     "fused_bytes_per_chip": cost["fused_bytes"]},
            "collectives": cost["coll"],
            "roofline": terms,
            "cost_compile_s": round(time.time() - t1, 2),
        })

    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        name = f"{arch}_{shape_name}_{result['mesh']}{suffix}.json"
        (RESULTS_DIR / name).write_text(json.dumps(result, indent=1))
    return result


def _fmt(result: dict) -> str:
    head = (f"{result['arch']:<18} {result['shape']:<12} "
            f"{result['mesh']:<8} compile={result['compile_s']:.0f}s")
    if "roofline" not in result:
        return head + " (proof only)"
    r = result["roofline"]
    mf = r.get("memory_fused_s", r["memory_s"])
    return (head + f" c={r['compute_s']:.3e} m={mf:.3e}"
            f"(up {r['memory_s']:.1e}) "
            f"x={r['collective_s']:.3e} dom={r['dominant'][:-2]:<10} "
            f"frac={r['roofline_fraction']:.3f}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--flgw-groups", type=int, default=1)
    ap.add_argument("--flgw-path", default="masked",
                    choices=("masked", "grouped"))
    ap.add_argument("--banded", action="store_true")
    ap.add_argument("--flash", action="store_true",
                    help="account the fused Pallas attention core")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (int/float/str)")
    ap.add_argument("--pure-dp", action="store_true",
                    help="replicate weights over the data axis (no FSDP)")
    ap.add_argument("--optimizer", default="adamw",
                    choices=("adamw", "rmsprop"))
    ap.add_argument("--cost-only", action="store_true",
                    help="skip the real-program proof compile")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the cost variants (proof + memory only)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    extra = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        extra[k] = v
    rules = None
    if args.pure_dp:
        from repro.sharding.partition import LOGICAL_RULES
        rules = dict(LOGICAL_RULES, embed=None)

    cells = (registry.all_cells() if args.all
             else [(args.arch, s) for s in
                   (registry.cells(args.arch) if args.shape is None
                    else [args.shape])])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                res = run_cell(arch, shape, multi_pod=mp,
                               flgw_groups=args.flgw_groups,
                               flgw_path=args.flgw_path,
                               banded=args.banded, flash=args.flash,
                               tag=args.tag, rules=rules, extra=extra,
                               optimizer=args.optimizer,
                               proof=not args.cost_only,
                               with_cost=not (mp or args.no_cost))
                print(_fmt(res), flush=True)
            except Exception as e:
                failures.append((arch, shape, mp, repr(e)[:200]))
                print(f"FAIL {arch} {shape} multi_pod={mp}: {e!r}"[:300],
                      flush=True)
    if failures:
        print(f"\n{len(failures)} failures")
        return 1
    print("\nall cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
