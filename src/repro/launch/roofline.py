"""Roofline-term derivation from a compiled (dry-run) artifact.

Three terms, in seconds, per §Roofline:

    compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory     = HLO_bytes   / (chips × HBM_bw)
    collective = coll_bytes  / (chips × link_bw)

``compiled.cost_analysis()`` reports the *per-device partitioned module*
(GSPMD compiles one SPMD program), so its flops/bytes are already per chip;
we normalize both conventions by recording chips explicitly and letting
``roofline_terms`` divide only the whole-program quantities.

collective_bytes is not in cost_analysis: ``collective_bytes_from_hlo``
parses the optimized HLO and sums operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, scaled by
the ring cost of its replica group (an n-way ring moves ≈ (n−1)/n of the
tensor per link for AG/RS, 2(n−1)/n for AR).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (values given by the assignment).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUP_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUP_V2_RE.search(line)          # [n_groups,group_size] form
    if m:
        return max(1, int(m.group(2)))
    m = _GROUP_RE.search(line)             # {{0,1,2,...},...} form
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind *per-chip link bytes* from optimized HLO text.

    For each collective instruction: tensor_bytes = max over the shapes on
    the line (covers both operand and result conventions), then ring-scaled
    by its replica group size n: AG/RS/permute move (n−1)/n of the tensor
    over links, AR moves 2(n−1)/n (reduce-scatter + all-gather phases),
    all-to-all (n−1)/n.
    """
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        kind = None
        for k in COLLECTIVES:
            # match the op name as the instruction, not inside metadata
            if re.search(rf"= [a-z0-9\[\],{{}}]* ?{k}[.\d]*\(", stripped) or \
               re.search(rf"\b{k}[.\d]*\(", stripped.split("=", 1)[-1]
                         if "=" in stripped else ""):
                kind = k
                break
        if kind is None:
            continue
        shapes = _SHAPE_RE.findall(stripped.split("metadata=")[0])
        sizes = [_shape_bytes(d, dims) for d, dims in shapes
                 if d in _DTYPE_BYTES]
        if not sizes:
            continue
        tensor = max(sizes)
        n = _group_size(stripped)
        if n <= 1:
            continue
        ring = (n - 1) / n
        scale = 2.0 * ring if kind == "all-reduce" else ring
        out[kind] += tensor * scale
        out["count"] += 1
    out["total"] = sum(out[k] for k in COLLECTIVES)
    return out


# Ops whose operands/results must touch HBM even under perfect fusion.
_HBM_OPS = ("dot", "convolution", "reduce", "reduce-window", "scatter",
            "gather", "dynamic-slice", "dynamic-update-slice", "sort",
            "rng-bit-generator", "iota")  # iota excluded below (generated)
_HBM_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(dot|convolution|reduce-window|reduce|scatter|gather|"
    r"dynamic-update-slice|dynamic-slice|sort|rng-bit-generator)[.\d]*\(")
_PARAM_RE = re.compile(r"=\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+parameter\(")


def fused_bytes_from_hlo(hlo_text: str) -> float:
    """Fusion-optimistic HBM bytes: a *lower bound* assuming a perfectly
    fusing compiler (TPU XLA is close for elementwise/convert/broadcast
    chains, which the CPU-backend module leaves unfused and which
    ``bytes accessed`` therefore multi-counts).

    Counted: every parameter once, plus all shapes appearing on
    dot / convolution / reduce / scatter / gather / dynamic-(update-)slice /
    sort / rng instructions (operands + result — these materialize), plus
    collective operands (already in the collective term, still HBM traffic).
    Elementwise, convert, broadcast, transpose, fusion wrappers: free
    (assumed fused into a neighbouring producer/consumer).
    """
    total = 0.0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _PARAM_RE.search(stripped)
        if m:
            total += _shape_bytes(m.group(1), m.group(2))
            continue
        if not _HBM_RE.search(stripped):
            continue
        shapes = _SHAPE_RE.findall(stripped.split("metadata=")[0])
        total += sum(_shape_bytes(d, dims) for d, dims in shapes
                     if d in _DTYPE_BYTES)
    return total


def roofline_terms(*, flops_per_chip: float, bytes_per_chip: float,
                   collective_bytes_per_chip: float,
                   model_flops_total: float, chips: int,
                   fused_bytes_per_chip: float = None) -> Dict[str, float]:
    compute = flops_per_chip / PEAK_FLOPS
    memory = bytes_per_chip / HBM_BW
    collective = collective_bytes_per_chip / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    if fused_bytes_per_chip is not None:
        # The honest estimate brackets: memory_s (per-op upper bound) ≥ TPU
        # ≥ memory_fused_s (perfect-fusion lower bound). Dominance and the
        # roofline fraction use the fused bound — closer to TPU behaviour.
        terms["memory_fused_s"] = fused_bytes_per_chip / HBM_BW
        decide = {"compute_s": compute,
                  "memory_s": terms["memory_fused_s"],
                  "collective_s": collective}
    else:
        decide = terms
    dominant = max(decide, key=decide.get)
    bound = max(decide.values())
    useful = model_flops_total / chips / PEAK_FLOPS
    return {
        **terms,
        "dominant": dominant,
        "step_time_lower_bound_s": bound,
        "model_flops_total": model_flops_total,
        "hlo_flops_per_chip": flops_per_chip,
        "useful_flops_ratio": (model_flops_total / chips) / flops_per_chip
        if flops_per_chip else float("nan"),
        "roofline_fraction": useful / bound if bound else float("nan"),
        "roofline_fraction_upper_bound_terms":
            useful / max(terms["compute_s"], terms["memory_s"], collective)
            if max(terms.values()) else float("nan"),
        "chips": chips,
    }


def flash_attention_cost(cfg, *, batch: int, seq: int, kind: str,
                         bq: int = 512, bk: int = 512) -> Dict[str, float]:
    """Analytic FLOPs/HBM-bytes of the fused attention cores of one step.

    Used by the ``--flash`` dry-run: HLO cost analysis cannot see inside a
    ``pallas_call`` (it is a custom call), so the measured cost of the
    *unfused* core is subtracted (identity-core variant diff) and this
    model is added. Convention:

    * pair count: exact allowed (q, k) pairs, block-rounded (the kernel
      skips only fully-masked (bq, bk) tiles);
    * matmul units of 2·pairs·D flops: fwd = 2 (qk, pv). train adds the
      remat recompute (+2) and the two bwd passes (dq: 3, dkv: 4) = 11;
    * softmax/online-rescale vector flops ≈ 8 per pair (fwd) ~ 20 (train);
    * HBM bytes: q/o/do/dq read+written once per pass; k/v streamed once
      per live (q-block row, head) — i.e. re-read ``live_rows`` times;
      lse/delta negligible. Everything else (projections, RoPE) stays in
      the measured HLO.
    """
    per_layer = []
    for slot in cfg.pattern:
        if slot.mixer != "attn":
            per_layer.append((0.0, 0.0))
            continue
        w = slot.window
        s = seq
        # exact allowed pairs
        if slot.causal:
            if w and w < s:
                pairs = w * s - w * (w - 1) / 2  # ramp then band
            else:
                pairs = s * (s + 1) / 2
        else:
            pairs = float(s) * s
        # block rounding: partial tiles compute fully
        pairs = min(pairs * 1.15 + bq * bk, float(s) * s)
        hq = cfg.n_heads
        hkv = cfg.n_kv_heads
        d = cfg.head_dim
        mm_units = 11 if kind == "train" else 2
        vec = 20 if kind == "train" else 8
        flops = batch * hq * (mm_units * 2 * pairs * d + vec * pairs)
        # bytes
        dt = 2  # bf16 operands
        passes = 3 if kind == "train" else 1          # fwd, dq, dkv
        qo_tensors = 8 if kind == "train" else 2      # q,o,do,dq r/w-ish
        live_blocks = pairs / (bq * bk)   # tiles that actually stream
        bytes_qo = batch * hq * s * d * dt * qo_tensors
        bytes_kv = (batch * hkv * live_blocks * bk * d * dt * 2 * passes)
        per_layer.append((flops, bytes_qo + bytes_kv))
    nb = cfg.n_blocks
    flops = nb * sum(f for f, _ in per_layer)
    bytes_ = nb * sum(b for _, b in per_layer)
    return {"flops": flops, "bytes": bytes_}


def model_flops(cfg, n_tokens: int, *, kind: str = "train") -> float:
    """6·N_active·D for train, 2·N_active·D for single forward/decode."""
    from repro.models.config import active_param_count
    n = active_param_count(cfg)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * n_tokens
