"""jamba-1.5-large-398b [hybrid]: 72L d=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2, Mamba:attention 7:1 interleave (1 attn +
7 SSM per 8-layer block), MoE every other layer. SSM state 128.
[arXiv:2403.19887; hf:ai21labs/AI21-Jamba-1.5-Large]"""
from repro.configs.registry import register, register_smoke
from repro.models.config import ModelConfig, SlotSpec


def _pattern():
    slots = []
    for i in range(8):
        mixer = "attn" if i == 0 else "ssm"
        ffn = "moe" if i % 2 == 1 else "mlp"
        slots.append(SlotSpec(mixer=mixer, window=0, ffn=ffn))
    return tuple(slots)


@register("jamba_1_5_large")
def config() -> ModelConfig:
    return ModelConfig(
        name="jamba_1_5_large", family="hybrid", n_layers=72, d_model=8192,
        n_heads=64, n_kv_heads=8, head_dim=128, d_ff=24576, vocab=65_536,
        pattern=_pattern(), n_experts=16, top_k=2, moe_d_ff=24576,
        ssm_state=128, ssm_head_dim=128, expand=2)


@register_smoke("jamba_1_5_large")
def smoke() -> ModelConfig:
    slots = []
    for i in range(8):
        mixer = "attn" if i == 0 else "ssm"
        ffn = "moe" if i % 2 == 1 else "mlp"
        slots.append(SlotSpec(mixer=mixer, window=0, ffn=ffn))
    return ModelConfig(
        name="jamba_1_5_large_smoke", family="hybrid", n_layers=8,
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
        vocab=512, pattern=tuple(slots), n_experts=4, top_k=2, moe_d_ff=128,
        ssm_state=16, ssm_head_dim=16, expand=2)
