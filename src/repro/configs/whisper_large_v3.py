"""whisper-large-v3 [audio]: enc-dec, 32L each, d=1280 20H (kv=20) d_ff=5120
vocab=51866. Conv/mel frontend is a STUB: input_specs provides 1500
precomputed frame embeddings. Decoder: causal self-attn + cross-attn.
Deviations (DESIGN.md): RoPE instead of learned/sinusoidal positions so long
decode shapes are well-defined; non-gated GELU MLP as published.
[arXiv:2212.04356; unverified tier]"""
from repro.configs.registry import register, register_smoke
from repro.models.config import ModelConfig, SlotSpec


@register("whisper_large_v3")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper_large_v3", family="audio", n_layers=32, d_model=1280,
        n_heads=20, n_kv_heads=20, head_dim=64, d_ff=5120, vocab=51_866,
        pattern=(SlotSpec(mixer="attn", window=0, ffn="mlp", cross=True),),
        encoder_layers=32, num_frames=1500, gated_mlp=False)


@register_smoke("whisper_large_v3")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper_large_v3_smoke", family="audio", n_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab=512,
        pattern=(SlotSpec(mixer="attn", window=0, ffn="mlp", cross=True),),
        encoder_layers=2, num_frames=24, gated_mlp=False)
