"""mamba2-1.3b [ssm]: 48L d=2048 attn-free, vocab=50280, ssm_state=128,
SSD (state-space duality), d_inner=2*d, head_dim=64 (64 SSM heads). Pure
mamba blocks — no FFN (d_ff=0 per assignment). [arXiv:2405.21060]"""
from repro.configs.registry import register, register_smoke
from repro.models.config import ModelConfig, SlotSpec


@register("mamba2_1_3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_1_3b", family="ssm", n_layers=48, d_model=2048,
        n_heads=1, n_kv_heads=1, head_dim=64, d_ff=0, vocab=50_280,
        pattern=(SlotSpec(mixer="ssm", ffn="none"),),
        ssm_state=128, ssm_head_dim=64, expand=2)


@register_smoke("mamba2_1_3b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2_1_3b_smoke", family="ssm", n_layers=4, d_model=64,
        n_heads=1, n_kv_heads=1, head_dim=16, d_ff=0, vocab=512,
        pattern=(SlotSpec(mixer="ssm", ffn="none"),),
        ssm_state=16, ssm_head_dim=16, expand=2)
