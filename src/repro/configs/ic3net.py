"""ic3net — the paper's own network (Singh et al., ICLR'19, as used by
LearningGroup §IV-A): per-agent LSTM policy with a gated communication
layer, hidden 128, trained with REINFORCE + value baseline, RMSprop lr=1e-3
on Predator-Prey. FLGW applies to every FC and LSTM gate projection."""
from repro.configs.registry import register, register_smoke
from repro.marl.ic3net import IC3NetConfig


@register("ic3net")
def config() -> IC3NetConfig:
    return IC3NetConfig(hidden=128, n_agents=8, flgw_groups=1)


@register_smoke("ic3net")
def smoke() -> IC3NetConfig:
    return IC3NetConfig(hidden=32, n_agents=3, flgw_groups=2)
