"""arctic-480b [moe]: 35L d=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 PLUS a parallel dense-residual FFN branch.
[hf:Snowflake/snowflake-arctic-base]"""
from repro.configs.registry import register, register_smoke
from repro.models.config import ModelConfig, SlotSpec


@register("arctic_480b")
def config() -> ModelConfig:
    return ModelConfig(
        name="arctic_480b", family="moe", n_layers=35, d_model=7168,
        n_heads=56, n_kv_heads=8, head_dim=128, d_ff=4864, vocab=32_000,
        pattern=(SlotSpec(mixer="attn", window=0, ffn="moe_dense"),),
        n_experts=128, top_k=2, moe_d_ff=4864)


@register_smoke("arctic_480b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="arctic_480b_smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96, vocab=512,
        pattern=(SlotSpec(mixer="attn", window=0, ffn="moe_dense"),),
        n_experts=8, top_k=2, moe_d_ff=96)
