"""paligemma-3b [vlm]: 18L d=2048 8H (GQA kv=1) d_ff=16384 vocab=257216.
SigLIP vision frontend is a STUB: input_specs provides 256 precomputed patch
embeddings; the backbone runs prefix-LM attention over [patches; text].
[arXiv:2407.07726; hf:google/paligemma-3b-pt-224]"""
from repro.configs.registry import register, register_smoke
from repro.models.config import ModelConfig, SlotSpec


@register("paligemma_3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma_3b", family="vlm", n_layers=18, d_model=2048,
        n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384, vocab=257_216,
        pattern=(SlotSpec(),), prefix_len=256)


@register_smoke("paligemma_3b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="paligemma_3b_smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab=512,
        pattern=(SlotSpec(),), prefix_len=8)
