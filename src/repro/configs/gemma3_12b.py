"""gemma3-12b [dense]: 48L d=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
5:1 local(1024):global attention pattern, 128k context.
[hf:google/gemma-3-12b-pt; unverified tier]"""
from repro.configs.registry import register, register_smoke
from repro.models.config import ModelConfig, SlotSpec

_LOCAL = SlotSpec(mixer="attn", window=1024, ffn="mlp")
_GLOBAL = SlotSpec(mixer="attn", window=0, ffn="mlp")
_PATTERN = (_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL)


@register("gemma3_12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3_12b", family="dense", n_layers=48, d_model=3840,
        n_heads=16, n_kv_heads=8, head_dim=256, d_ff=15360, vocab=262_144,
        pattern=_PATTERN, rope_theta=1_000_000.0)


@register_smoke("gemma3_12b")
def smoke() -> ModelConfig:
    l = SlotSpec(mixer="attn", window=16, ffn="mlp")
    g = SlotSpec(mixer="attn", window=0, ffn="mlp")
    return ModelConfig(
        name="gemma3_12b_smoke", family="dense", n_layers=6, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        pattern=(l, l, l, l, l, g))
