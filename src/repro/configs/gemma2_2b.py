"""gemma2-2b [dense]: 26L d=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Local+global alternating attention (window 4096), attn/final logit softcaps.
[arXiv:2408.00118; hf:google/gemma-2-2b]"""
from repro.configs.registry import register, register_smoke
from repro.models.config import ModelConfig, SlotSpec

_PATTERN = (SlotSpec(mixer="attn", window=4096, ffn="mlp"),
            SlotSpec(mixer="attn", window=0, ffn="mlp"))


@register("gemma2_2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2_2b", family="dense", n_layers=26, d_model=2304,
        n_heads=8, n_kv_heads=4, head_dim=256, d_ff=9216, vocab=256_000,
        pattern=_PATTERN, attn_softcap=50.0, logit_softcap=30.0)


@register_smoke("gemma2_2b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2_2b_smoke", family="dense", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        pattern=(SlotSpec(mixer="attn", window=16, ffn="mlp"),
                 SlotSpec(mixer="attn", window=0, ffn="mlp")),
        attn_softcap=50.0, logit_softcap=30.0)
