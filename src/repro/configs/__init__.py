from repro.configs.registry import (  # noqa: F401
    ARCH_IDS, SHAPES, all_cells, cells, get_config, get_smoke_config,
    input_specs,
)
