"""internlm2-20b [dense]: 48L d=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
Full (global) attention, GQA. [arXiv:2403.17297; hf:internlm/internlm2-20b]"""
from repro.configs.registry import register, register_smoke
from repro.models.config import ModelConfig, SlotSpec


@register("internlm2_20b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internlm2_20b", family="dense", n_layers=48, d_model=6144,
        n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384, vocab=92_544,
        pattern=(SlotSpec(),), rope_theta=1_000_000.0)


@register_smoke("internlm2_20b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="internlm2_20b_smoke", family="dense", n_layers=2, d_model=96,
        n_heads=6, n_kv_heads=2, head_dim=16, d_ff=192, vocab=512,
        pattern=(SlotSpec(),))
