"""mixtral-8x22b [moe]: 56L d=6144 48H (GQA kv=8) vocab=32768,
MoE 8 experts top-2 (expert d_ff=16384), sliding-window attention.
[arXiv:2401.04088; hf:mistralai/Mixtral-8x22B-v0.1]"""
from repro.configs.registry import register, register_smoke
from repro.models.config import ModelConfig, SlotSpec


@register("mixtral_8x22b")
def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral_8x22b", family="moe", n_layers=56, d_model=6144,
        n_heads=48, n_kv_heads=8, head_dim=128, d_ff=16384, vocab=32_768,
        pattern=(SlotSpec(mixer="attn", window=4096, ffn="moe"),),
        n_experts=8, top_k=2, moe_d_ff=16384)


@register_smoke("mixtral_8x22b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral_8x22b_smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab=512,
        pattern=(SlotSpec(mixer="attn", window=16, ffn="moe"),),
        n_experts=4, top_k=2, moe_d_ff=128)
