"""gemma2-27b [dense]: 46L d=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
Local+global alternating attention, logit softcaps. [arXiv:2408.00118; hf]"""
from repro.configs.registry import register, register_smoke
from repro.models.config import ModelConfig, SlotSpec

_PATTERN = (SlotSpec(mixer="attn", window=4096, ffn="mlp"),
            SlotSpec(mixer="attn", window=0, ffn="mlp"))


@register("gemma2_27b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2_27b", family="dense", n_layers=46, d_model=4608,
        n_heads=32, n_kv_heads=16, head_dim=128, d_ff=36864, vocab=256_000,
        pattern=_PATTERN, attn_softcap=50.0, logit_softcap=30.0)


@register_smoke("gemma2_27b")
def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2_27b_smoke", family="dense", n_layers=4, d_model=64,
        n_heads=8, n_kv_heads=4, head_dim=8, d_ff=192, vocab=512,
        pattern=(SlotSpec(mixer="attn", window=16, ffn="mlp"),
                 SlotSpec(mixer="attn", window=0, ffn="mlp")),
        attn_softcap=50.0, logit_softcap=30.0)
