"""Architecture registry: ``--arch <id>`` lookup + input shape cells.

Every assigned architecture registers its exact published config, a reduced
smoke config (same family, tiny dims) and its shape-cell applicability.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}

ARCH_IDS = (
    "gemma2_2b", "gemma2_27b", "gemma3_12b", "internlm2_20b",
    "paligemma_3b", "mixtral_8x22b", "arctic_480b", "whisper_large_v3",
    "jamba_1_5_large", "mamba2_1_3b", "ic3net",
)

# Shape cells (assignment): name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k":    (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k":  (32_768, 128, "decode"),
    "long_500k":   (524_288, 1, "decode"),
}

# long_500k policy (DESIGN.md §6): sub-quadratic / bounded-KV archs only.
LONG_OK = {"mamba2_1_3b", "jamba_1_5_large", "mixtral_8x22b", "gemma3_12b"}
# ic3net is the paper's own network: MARL shapes only (no LM shape cells).
NO_LM_SHAPES = {"ic3net"}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def register_smoke(name: str):
    def deco(fn):
        _SMOKE[name] = fn
        return fn
    return deco


def _load(name: str):
    if name not in _REGISTRY:
        importlib.import_module(f"repro.configs.{name}")


def get_config(name: str, **overrides) -> ModelConfig:
    _load(name)
    cfg = _REGISTRY[name]()
    return cfg.with_updates(**overrides) if overrides else cfg


def get_smoke_config(name: str, **overrides) -> ModelConfig:
    _load(name)
    cfg = _SMOKE[name]()
    return cfg.with_updates(**overrides) if overrides else cfg


def cells(arch: str) -> list[str]:
    """Shape cells applicable to this arch (skips documented in DESIGN.md)."""
    if arch in NO_LM_SHAPES:
        return []
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in LONG_OK:
        out.append("long_500k")
    return out


def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in cells(a)]


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    No device allocation — the dry-run lowers against these directly.
    """
    seq, batch, kind = SHAPES[shape_name]
    i32 = jnp.int32
    if kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
            "targets": jax.ShapeDtypeStruct((batch, seq), i32),
            "positions": jax.ShapeDtypeStruct((batch, seq), i32),
        }
        if cfg.prefix_len:  # vlm stub: precomputed patch embeddings
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.prefix_len, cfg.d_model), cfg.dtype)
        if cfg.encoder_layers:  # audio stub: precomputed frame embeddings
            specs["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.num_frames, cfg.d_model), cfg.dtype)
        return specs
    if kind == "prefill":
        specs = {
            "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
            "positions": jax.ShapeDtypeStruct((batch, seq), i32),
        }
        if cfg.prefix_len:
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (batch, cfg.prefix_len, cfg.d_model), cfg.dtype)
        if cfg.encoder_layers:
            specs["frames"] = jax.ShapeDtypeStruct(
                (batch, cfg.num_frames, cfg.d_model), cfg.dtype)
        return specs
    # decode: one new token against a seq-length cache (built separately)
    return {
        "tokens": jax.ShapeDtypeStruct((batch, 1), i32),
        "positions": jax.ShapeDtypeStruct((batch, 1), i32),
    }
