"""Flash attention Pallas kernel: shape/dtype/feature sweeps vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fops
from repro.kernels.flash_attention import ref as fref


def _mk(b, hq, hkv, s, t, d, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (b, hq, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, hkv, t, d),
                          jnp.float32).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, t, d),
                          jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("b,hq,hkv,s,d", [
    (2, 4, 2, 256, 64), (1, 4, 1, 128, 64), (2, 2, 2, 256, 32),
    (1, 8, 4, 256, 128),
])
@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 64, 0.0), (True, 0, 50.0), (False, 0, 0.0),
    (True, 128, 30.0),
])
def test_flash_forward_matches_ref(b, hq, hkv, s, d, causal, window,
                                   softcap):
    q, k, v = _mk(b, hq, hkv, s, s, d, jnp.float32)
    got = fops.flash_attention(q, k, v, causal, window, softcap, None,
                               128, 128, True)
    want = fref.ref_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=2e-5)


@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 64, 0.0), (True, 0, 30.0), (False, 0, 0.0),
])
def test_flash_backward_matches_ref(causal, window, softcap):
    b, hq, hkv, s, d = 2, 4, 2, 256, 64
    q, k, v = _mk(b, hq, hkv, s, s, d, jnp.float32)
    go = jax.random.normal(jax.random.PRNGKey(3), q.shape)

    def f_flash(q, k, v):
        return jnp.sum(fops.flash_attention(
            q, k, v, causal, window, softcap, None, 128, 128, True) * go)

    def f_ref(q, k, v):
        return jnp.sum(fref.ref_attention(
            q, k, v, causal=causal, window=window, softcap=softcap) * go)

    g1 = jax.grad(f_flash, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, (0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)


def test_flash_bf16_tolerance():
    q, k, v = _mk(1, 4, 2, 256, 256, 64, jnp.bfloat16)
    got = fops.flash_attention(q, k, v, True, 0, 0.0, None, 128, 128, True)
    want = fref.ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_uneven_blocks():
    # s=384 with bq=256 -> falls back to a dividing block size
    q, k, v = _mk(1, 2, 1, 384, 384, 64, jnp.float32)
    got = fops.flash_attention(q, k, v, True, 0, 0.0, None, 256, 256, True)
    want = fref.ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=2e-5)


def test_flash_in_model_matches_chunked_path():
    """End-to-end: gemma2 smoke (softcap + local/global) flash vs chunked."""
    from repro.configs import registry
    from repro.models import transformer
    cfg = registry.get_smoke_config("gemma2_2b")
    params, _ = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab,
                              jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32), (2, 64))
    l1, _, _ = transformer.lm_apply(params, cfg, toks, pos, remat=False)
    l2, _, _ = transformer.lm_apply(params, cfg.with_updates(use_flash=True),
                                    toks, pos, remat=False)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=5e-2, atol=5e-2)
