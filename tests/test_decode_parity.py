"""Decode-vs-prefill parity: the strongest integration test in the repo.

Token-by-token decoding through the (ring-buffer) KV / SSM caches must
reproduce the cache-free full-sequence forward — including sliding-window
layers whose cache is shorter than the stream (the ring buffer wraps).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer
from repro.models.config import ModelConfig, SlotSpec


def _full_then_decode(cfg, seq, key=0, atol=2e-2):
    k = jax.random.PRNGKey(key)
    params, _ = transformer.lm_init(k, cfg)
    b = 2
    tokens = jax.random.randint(jax.random.fold_in(k, 1), (b, seq), 0,
                                cfg.vocab, jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (b, seq))

    # serving-semantics reference: dropless MoE (decode is dropless too)
    full_logits, _, _ = transformer.lm_apply(params, cfg, tokens, positions,
                                             remat=False, moe_dropless=True)

    cache = transformer.init_cache(cfg, b, seq)
    step_logits = []
    apply = jax.jit(lambda p, t, pos, c: transformer.lm_apply(
        p, cfg, t, pos, cache=c, remat=False))
    for t in range(seq):
        lg, _, cache = apply(params, tokens[:, t:t + 1],
                             positions[:, t:t + 1], cache)
        step_logits.append(lg[:, 0])
    decode_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(decode_logits, jnp.float32),
        np.asarray(full_logits, jnp.float32), rtol=2e-2, atol=atol)


def test_parity_global_attention():
    cfg = registry.get_smoke_config("internlm2_20b")
    _full_then_decode(cfg, seq=12)


def test_parity_sliding_window_ring_buffer_wraps():
    """seq > window: the ring buffer must overwrite old positions and the
    decode output must still match the windowed full forward."""
    cfg = ModelConfig(
        name="swa_test", family="dense", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab=128,
        pattern=(SlotSpec(mixer="attn", window=4, ffn="mlp"),), remat=False)
    # cache length = window (4) < seq (12): three full wraps
    _full_then_decode(cfg, seq=12)


def test_parity_alternating_local_global():
    cfg = registry.get_smoke_config("gemma2_2b")   # window 16 slots
    _full_then_decode(cfg, seq=24)                 # exceeds local window


def test_parity_ssm_decode():
    cfg = registry.get_smoke_config("mamba2_1_3b")
    _full_then_decode(cfg, seq=10, atol=5e-2)


@pytest.mark.slow
def test_parity_hybrid_jamba():
    cfg = registry.get_smoke_config("jamba_1_5_large")
    _full_then_decode(cfg, seq=8, atol=5e-2)


def test_parity_moe_decode():
    cfg = registry.get_smoke_config("mixtral_8x22b")
    _full_then_decode(cfg, seq=8, atol=5e-2)


def test_windowed_cache_is_bounded():
    """init_cache allocates min(max_seq, window) for SWA slots."""
    cfg = ModelConfig(
        name="swa", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
        pattern=(SlotSpec(mixer="attn", window=4, ffn="mlp"),
                 SlotSpec(mixer="attn", window=0, ffn="mlp")))
    cache = transformer.init_cache(cfg, 1, 1024)
    assert cache["blocks"]["slot0"]["k"].shape[2] == 4       # bounded
    assert cache["blocks"]["slot1"]["k"].shape[2] == 1024    # global
