"""Decode-vs-prefill parity: the strongest integration test in the repo.

Token-by-token decoding through the (ring-buffer) KV / SSM caches must
reproduce the cache-free full-sequence forward — including sliding-window
layers whose cache is shorter than the stream (the ring buffer wraps).

The grouped-serving section checks the plan-amortization contract: decode
against the PlanState cached beside the KV/SSM caches must be *bitwise*
equal to the plan=None per-call re-encoding path, for attention, SSM and
MoE FLGW targets alike.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import encoder
from repro.models import transformer
from repro.models.config import ModelConfig, SlotSpec


def _full_then_decode(cfg, seq, key=0, atol=2e-2):
    k = jax.random.PRNGKey(key)
    params, _ = transformer.lm_init(k, cfg)
    b = 2
    tokens = jax.random.randint(jax.random.fold_in(k, 1), (b, seq), 0,
                                cfg.vocab, jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (b, seq))

    # serving-semantics reference: dropless MoE (decode is dropless too)
    full_logits, _, _ = transformer.lm_apply(params, cfg, tokens, positions,
                                             remat=False, moe_dropless=True)

    cache = transformer.init_cache(cfg, b, seq)
    step_logits = []
    apply = jax.jit(lambda p, t, pos, c: transformer.lm_apply(
        p, cfg, t, pos, cache=c, remat=False))
    for t in range(seq):
        lg, _, cache = apply(params, tokens[:, t:t + 1],
                             positions[:, t:t + 1], cache)
        step_logits.append(lg[:, 0])
    decode_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(decode_logits, jnp.float32),
        np.asarray(full_logits, jnp.float32), rtol=2e-2, atol=atol)


def test_parity_global_attention():
    cfg = registry.get_smoke_config("internlm2_20b")
    _full_then_decode(cfg, seq=12)


def test_parity_sliding_window_ring_buffer_wraps():
    """seq > window: the ring buffer must overwrite old positions and the
    decode output must still match the windowed full forward."""
    cfg = ModelConfig(
        name="swa_test", family="dense", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab=128,
        pattern=(SlotSpec(mixer="attn", window=4, ffn="mlp"),), remat=False)
    # cache length = window (4) < seq (12): three full wraps
    _full_then_decode(cfg, seq=12)


def test_parity_alternating_local_global():
    cfg = registry.get_smoke_config("gemma2_2b")   # window 16 slots
    _full_then_decode(cfg, seq=24)                 # exceeds local window


def test_parity_ssm_decode():
    cfg = registry.get_smoke_config("mamba2_1_3b")
    _full_then_decode(cfg, seq=10, atol=5e-2)


@pytest.mark.slow
def test_parity_hybrid_jamba():
    cfg = registry.get_smoke_config("jamba_1_5_large")
    _full_then_decode(cfg, seq=8, atol=5e-2)


def test_parity_moe_decode():
    cfg = registry.get_smoke_config("mixtral_8x22b")
    _full_then_decode(cfg, seq=8, atol=5e-2)


# ---------------------------------------------------------------------------
# Grouped serving: cached PlanState vs per-call re-encoding (bitwise)
# ---------------------------------------------------------------------------

def _grouped_serve_bitwise(cfg, seq):
    """Prefill (prompt replay, as examples/serve.py) + decode twice — once
    with the PlanState beside the KV cache, once plan-less — and demand
    bitwise-identical logits at every step."""
    k = jax.random.PRNGKey(3)
    params, _ = transformer.lm_init(k, cfg)
    b = 1
    tokens = jax.random.randint(jax.random.fold_in(k, 1), (b, seq), 0,
                                cfg.vocab, jnp.int32)
    positions = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (b, seq))
    apply = jax.jit(lambda p, t, pos, c: transformer.lm_apply(
        p, cfg, t, pos, cache=c, remat=False))

    runs = {}
    for cached in (True, False):
        cache = transformer.init_cache(cfg, b, seq,
                                       params=params if cached else None)
        assert isinstance(cache["plans"],
                          encoder.PlanState if cached else tuple)
        logits = []
        for t in range(seq):                # prefill replay + decode steps
            lg, _, cache = apply(params, tokens[:, t:t + 1],
                                 positions[:, t:t + 1], cache)
            logits.append(np.asarray(lg[:, 0]))  # noqa: ANL002 — parity test materializes every step deliberately
        runs[cached] = np.stack(logits, axis=1)
        if cached:                          # plans ride the cache unchanged
            assert isinstance(cache["plans"], encoder.PlanState)
    np.testing.assert_array_equal(runs[True], runs[False])


def _grouped(**kw):
    base = dict(flgw_groups=4, flgw_path="grouped", dtype=jnp.float32,
                remat=False, vocab=64, d_model=32, n_heads=2, n_kv_heads=2,
                head_dim=16, d_ff=64, n_layers=2)
    base.update(kw)
    return ModelConfig(**base)


def test_grouped_serve_parity_attention_slots():
    cfg = _grouped(name="g_attn", family="dense",
                   flgw_targets=("mlp", "attn"))
    _grouped_serve_bitwise(cfg, seq=6)


def test_grouped_serve_parity_ssm_slots():
    cfg = _grouped(name="g_ssm", family="ssm",
                   pattern=(SlotSpec(mixer="ssm", ffn="mlp"),),
                   ssm_state=8, ssm_head_dim=16,
                   flgw_targets=("ssm", "mlp"))
    _grouped_serve_bitwise(cfg, seq=5)


def test_grouped_serve_parity_moe_slots():
    cfg = _grouped(name="g_moe", family="moe",
                   pattern=(SlotSpec(mixer="attn", ffn="moe"),),
                   n_experts=2, top_k=2, moe_d_ff=32,
                   flgw_targets=("moe", "attn"))
    _grouped_serve_bitwise(cfg, seq=5)


def test_windowed_cache_is_bounded():
    """init_cache allocates min(max_seq, window) for SWA slots."""
    cfg = ModelConfig(
        name="swa", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
        pattern=(SlotSpec(mixer="attn", window=4, ffn="mlp"),
                 SlotSpec(mixer="attn", window=0, ffn="mlp")))
    cache = transformer.init_cache(cfg, 1, 1024)
    assert cache["blocks"]["slot0"]["k"].shape[2] == 4       # bounded
    assert cache["blocks"]["slot1"]["k"].shape[2] == 1024    # global
