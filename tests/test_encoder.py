"""Encoder subsystem: PlanState structure, signatures, refresh modes, and
the LM decoder stack's cached plans (no per-projection re-encode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import trace_counter
from repro.core import encoder, grouped
from repro.core.flgw import FLGWConfig
from repro.core.schedule import SparsitySchedule
from repro.marl import ic3net
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.train import state as state_lib
from repro.serving import make_decode_step, make_prefill_step
from repro.train import step as step_lib

FL = FLGWConfig(groups=4, path="grouped")


def _tiny_lm_cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=2, d_model=32,
                n_heads=2, n_kv_heads=2, d_ff=64, vocab=64, head_dim=16,
                flgw_groups=4, flgw_path="grouped", dtype=jnp.float32)
    base.update(kw)
    return ModelConfig(**base)


def _ic3net_params(seed=0):
    cfg = ic3net.IC3NetConfig(hidden=16, obs_dim=7, flgw_groups=4,
                              flgw_path="grouped")
    return ic3net.init(jax.random.PRNGKey(seed), cfg)[0], cfg


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# Plan structure
# ---------------------------------------------------------------------------

def test_transpose_plan_is_an_involution():
    params, _ = _ic3net_params()
    plan = grouped.make_plan(params["enc"]["ig"], params["enc"]["og"], 1.25)
    assert _tree_equal(grouped.transpose_plan(grouped.transpose_plan(plan)),
                       plan)


def _plan_leaf_paths(plans, _path=()):
    for name, p in sorted(plans.items()):
        if isinstance(p, grouped.GroupPlan):
            yield (*_path, name)
        else:
            yield from _plan_leaf_paths(p, (*_path, name))


def test_encode_plans_structure_mirrors_iter_flgw_layers():
    """One encoder for every workload: on a nested IC3Net + decoder param
    tree the PlanState has exactly one GroupPlan per FLGW layer, at the
    same path."""
    marl_params, _ = _ic3net_params()
    cfg = _tiny_lm_cfg()
    lm_params, _ = transformer.lm_init(jax.random.PRNGKey(1), cfg)
    tree = {"ic3net": marl_params, "decoder": lm_params}
    state = encoder.encode_plans(tree, FL)
    want = sorted(path for path, _ in grouped.iter_flgw_layers(tree))
    got = sorted(_plan_leaf_paths(state.plans))
    assert got == want
    assert len(want) > 5          # both subsystems actually contribute


def test_decoder_plans_are_stacked_like_their_params():
    """Scanned blocks carry stacked params -> stacked plans (same leading
    axis), so they slice per block as scan xs."""
    cfg = _tiny_lm_cfg()
    params, _ = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    state = transformer.encode_plans(params, cfg)
    ffn = state.plans["blocks"]["slot0"]["ffn"]
    for name in ("up", "gate", "down"):
        plan = ffn[name]
        ig = params["blocks"]["slot0"]["ffn"][name]["ig"]
        assert plan.row_ids.shape[0] == cfg.n_blocks == ig.shape[0]
        # each block's stacked plan equals the per-block encode
        one = grouped.make_plan(ig[1], params["blocks"]["slot0"]["ffn"]
                                [name]["og"][1], FL.capacity_slack)
        assert _tree_equal(jax.tree.map(lambda a: a[1], plan), one)


# ---------------------------------------------------------------------------
# Signature + refresh modes
# ---------------------------------------------------------------------------

def _flip_one_argmax(params, layer="enc"):
    """Flip row 0's argmax of one layer's IG, leaving all else untouched."""
    p = jax.tree.map(lambda a: a, params)
    ig = p[layer]["ig"]
    g = ig.shape[1]
    cur = int(jnp.argmax(ig[0]))
    new = (cur + 1) % g
    p[layer] = dict(p[layer], ig=ig.at[0, new].set(jnp.max(ig[0]) + 1.0))
    return p


def _nudge_without_flip(params):
    """Perturb every grouping matrix without moving any argmax."""
    def nudge(path_p):
        return dict(path_p, ig=path_p["ig"] * 1.0001,
                    og=path_p["og"] * 1.0001)
    p = {k: (nudge(v) if isinstance(v, dict) and "ig" in v else v)
         for k, v in params.items()}
    for (a, _), (b, _) in zip(grouped.iter_flgw_layers(params),
                              grouped.iter_flgw_layers(p)):
        assert a == b
    return p


def test_signature_changes_iff_an_argmax_flips():
    params, _ = _ic3net_params()
    sig = encoder.plan_signature(params)
    assert np.asarray(sig) == np.asarray(encoder.plan_signature(params))
    nudged = _nudge_without_flip(params)
    assert np.asarray(encoder.plan_signature(nudged)) == np.asarray(sig)
    flipped = _flip_one_argmax(params)
    assert np.asarray(encoder.plan_signature(flipped)) != np.asarray(sig)


def test_refresh_on_change_fires_exactly_on_argmax_flip():
    """on_change: a nudge that moves strengths but no argmax keeps the
    carried plans bitwise; one flipped argmax re-encodes."""
    params, cfg = _ic3net_params()
    state = ic3net.encode_plans(params, cfg)
    sched = SparsitySchedule(groups=4, refresh_every=1, refresh="on_change")
    refresh = jax.jit(encoder.maybe_refresh,
                      static_argnames=("cfg", "schedule"))

    nudged = _nudge_without_flip(params)
    kept = refresh(nudged, state, 1, cfg=FL, schedule=sched)
    assert _tree_equal(kept, state)          # no flip -> bitwise stale reuse

    flipped = _flip_one_argmax(params)
    got = refresh(flipped, state, 2, cfg=FL, schedule=sched)
    want = encoder.encode_plans(flipped, FL)
    assert _tree_equal(got, want)            # flip -> fresh encode


def test_refresh_hybrid_bounds_staleness_by_period():
    """hybrid: even with no argmax flip, the refresh_every boundary forces
    a re-encode (covers spill-order drift from moving strengths)."""
    params, cfg = _ic3net_params()
    stale = ic3net.encode_plans(params, cfg)
    moved = _nudge_without_flip(params)
    sched = SparsitySchedule(groups=4, refresh_every=3, refresh="hybrid")
    refresh = jax.jit(encoder.maybe_refresh,
                      static_argnames=("cfg", "schedule"))
    off = refresh(moved, stale, 1, cfg=FL, schedule=sched)
    assert _tree_equal(off, stale)           # not due, no flip
    on = refresh(moved, stale, 3, cfg=FL, schedule=sched)
    assert _tree_equal(on, encoder.encode_plans(moved, FL))


def test_on_change_parity_with_per_step_encoding():
    """The acceptance bar: along a param trajectory, change-driven refresh
    equals per-step re-encoding on every step whose hash changed, and
    reuses the carry bitwise otherwise."""
    params, cfg = _ic3net_params()
    sched = SparsitySchedule(groups=4, refresh_every=1, refresh="on_change")
    refresh = jax.jit(encoder.maybe_refresh,
                      static_argnames=("cfg", "schedule"))
    state = ic3net.encode_plans(params, cfg)
    seq = [_nudge_without_flip(params),
           _flip_one_argmax(params),
           _flip_one_argmax(_flip_one_argmax(params), layer="comm"),
           _flip_one_argmax(_flip_one_argmax(params), layer="comm")]
    for t, p in enumerate(seq, start=1):
        changed = (np.asarray(encoder.plan_signature(p))  # noqa: ANL002 — refresh-mode test compares signatures per step by design
                   != np.asarray(state.sig))  # noqa: ANL002 — same: the per-step comparison is the test
        prev = state
        state = refresh(p, state, t, cfg=FL, schedule=sched)
        if changed:
            assert _tree_equal(state, encoder.encode_plans(p, FL))
        else:
            assert _tree_equal(state, prev)


def test_schedule_rejects_unknown_refresh_mode():
    with pytest.raises(ValueError):
        SparsitySchedule(groups=4, refresh="sometimes")


def _spill_drift_pair():
    """Two grouping-matrix sets whose argmaxes agree but whose balanced
    layouts differ bitwise: group 0 is over capacity (6 rows, cap 5 at
    slack 1.25), and swapping two rows' strengths changes which row is
    least confident — i.e. which one spills."""
    ig = np.zeros((8, 2), np.float32)
    ig[:6, 0] = [9., 8., 7., 6., 5., 4.]
    ig[6:, 1] = [3., 2.]
    og = np.zeros((2, 8), np.float32)
    og[0, :4] = 1.0
    og[1, 4:] = 1.0
    ig2 = ig.copy()
    ig2[2, 0], ig2[5, 0] = 4., 7.          # strength swap, no argmax flip
    old = {"enc": {"ig": jnp.asarray(ig), "og": jnp.asarray(og)}}
    new = {"enc": {"ig": jnp.asarray(ig2), "og": jnp.asarray(og)}}
    assert np.array_equal(np.argmax(ig, 1), np.argmax(ig2, 1))
    return old, new


def test_signature_catches_spill_order_drift():
    """Regression (ROADMAP encoder follow-up): ``slack > 1`` overflow
    order depends on preference *strengths* — a reorder without any
    argmax flip moves the plan bitwise, and the layout-rank signature
    must move with it."""
    old, new = _spill_drift_pair()
    plan_old = grouped.make_plan(old["enc"]["ig"], old["enc"]["og"],
                                 FL.capacity_slack)
    plan_new = grouped.make_plan(new["enc"]["ig"], new["enc"]["og"],
                                 FL.capacity_slack)
    assert not _tree_equal(plan_old, plan_new)      # the drift is real
    assert np.asarray(encoder.plan_signature(old)) != \
        np.asarray(encoder.plan_signature(new))


def test_refresh_on_change_fires_on_spill_order_drift():
    """on_change must re-encode on spill-order drift, not only on argmax
    flips — the stale carried plan is bitwise-different from a fresh
    encode of the drifted matrices."""
    old, new = _spill_drift_pair()
    state = encoder.encode_plans(old, FL)
    sched = SparsitySchedule(groups=4, refresh_every=1000,
                             refresh="on_change")
    refresh = jax.jit(encoder.maybe_refresh,
                      static_argnames=("cfg", "schedule"))
    kept = refresh(old, state, 1, cfg=FL, schedule=sched)
    assert _tree_equal(kept, state)                  # no drift -> reuse
    fired = refresh(new, state, 2, cfg=FL, schedule=sched)
    assert _tree_equal(fired, encoder.encode_plans(new, FL))


# ---------------------------------------------------------------------------
# LM decoder stack: cached plans end to end
# ---------------------------------------------------------------------------

def _lm_batch(cfg, b=2, s=16):
    tok = jnp.zeros((b, s), jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return {"tokens": tok, "targets": tok, "positions": pos}


def test_lm_apply_with_plans_never_traces_make_plan():
    """Regression guard for the decoder-stack amortization: with a
    PlanState supplied, tracing the forward hits make_plan zero times; the
    plan=None fallback re-encodes once per FLGW projection."""
    cfg = _tiny_lm_cfg()
    params, _ = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    plans = transformer.encode_plans(params, cfg)
    batch = _lm_batch(cfg)
    with trace_counter(grouped, "make_plan") as calls:
        jax.eval_shape(
            lambda p, pl: transformer.lm_apply(
                p, cfg, batch["tokens"], batch["positions"], plans=pl,
                return_hidden=True),
            params, plans)
        assert calls.count == 0

        jax.eval_shape(
            lambda p: transformer.lm_apply(
                p, cfg, batch["tokens"], batch["positions"],
                return_hidden=True),
            params)
        assert calls.count == 3   # up/gate/down re-encoded per projection


def test_lm_train_step_encodes_once_per_refresh():
    """Tracing one LM train step hits make_plan exactly once per FLGW
    layer — inside the refresh cond — not per projection."""
    cfg = _tiny_lm_cfg()
    state = state_lib.init_state(jax.random.PRNGKey(0), cfg,
                                 optimizer="rmsprop")
    assert isinstance(state.plans, encoder.PlanState)
    step = step_lib.make_train_step(
        cfg, optimizer="rmsprop",
        schedule=SparsitySchedule(groups=4, refresh_every=2))
    with trace_counter(grouped, "make_plan") as calls:
        jax.eval_shape(step, state, _lm_batch(cfg))
    assert calls.count == 3       # one encode per FLGW layer, in the cond


def test_serve_step_with_cached_planstate_never_traces_make_plan():
    """The serving acceptance bar: with the PlanState beside the KV cache,
    tracing the decode step hits make_plan zero times even when mixers
    (attention here) are FLGW targets — no slot falls back to plan=None."""
    cfg = _tiny_lm_cfg(flgw_targets=("mlp", "attn"), remat=False)
    params, _ = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    cache = transformer.init_cache(cfg, 1, 8, params=params)
    assert isinstance(cache["plans"], encoder.PlanState)
    serve = make_decode_step(cfg)
    tok = jnp.zeros((1, 1), jnp.int32)
    with trace_counter(grouped, "make_plan") as calls:
        jax.eval_shape(serve, params, cache, tok, tok)
        assert calls.count == 0

        # the plan-less cache falls back to one encode per FLGW projection
        bare = transformer.init_cache(cfg, 1, 8)
        jax.eval_shape(serve, params, bare, tok, tok)
        assert calls.count == 7   # q/k/v/o + up/gate/down


def test_prefill_step_encodes_once_per_layer():
    """Prefill encodes the PlanState once (batched over blocks, one
    make_plan per FLGW layer) and every projection consumes it. A
    caller-supplied PlanState is *certified* at the request boundary
    (serving-staleness fix): the signature-gated refresh traces one
    conditional encode — still once per layer, never per projection —
    and at runtime re-encodes only when the layout actually moved
    (the fresh-plans no-op is pinned bitwise in
    tests/test_serving_refresh.py)."""
    cfg = _tiny_lm_cfg(flgw_targets=("mlp", "attn"), remat=False)
    params, _ = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    plans = transformer.encode_plans(params, cfg)
    prefill = make_prefill_step(cfg)
    batch = _lm_batch(cfg)
    with trace_counter(grouped, "make_plan") as calls:
        jax.eval_shape(prefill, params, batch)
        assert calls.count == 7   # one per FLGW layer, not per projection
        calls.reset()
        jax.eval_shape(prefill, params, batch, plans)
        # the certification branch traces the same once-per-layer encode
        # (inside lax.cond — zero encodes execute while the plans are
        # fresh)
        assert calls.count == 7


def test_lm_train_step_runs_and_carries_plans():
    """End to end on the grouped path: losses finite, plans ride the
    state, and on_change refresh keeps the step jittable."""
    cfg = _tiny_lm_cfg()
    state = state_lib.init_state(jax.random.PRNGKey(0), cfg,
                                 optimizer="rmsprop")
    step = jax.jit(step_lib.make_train_step(
        cfg, optimizer="rmsprop", lr=1e-2,
        schedule=SparsitySchedule(groups=4, refresh="on_change")))
    batch = _lm_batch(cfg)
    for _ in range(3):
        state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert isinstance(state.plans, encoder.PlanState)
    assert int(state.step) == 3
