"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
asserting output shapes and no NaNs — the assignment's required smokes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer
from repro.serving import make_decode_step
from repro.train import state as state_lib
from repro.train import step as step_lib

# big smoke configs compile for minutes on CPU; tier-1 keeps the small ones
_HEAVY_ARCHS = {"jamba_1_5_large", "gemma3_12b", "gemma2_27b",
                "internlm2_20b", "mixtral_8x22b", "arctic_480b"}
LM_ARCHS = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_ARCHS
            else a for a in registry.ARCH_IDS if a != "ic3net"]


def _batch(cfg, b=2, s=32):
    batch = {
        "tokens": jnp.ones((b, s), jnp.int32),
        "targets": jnp.ones((b, s), jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                      (b, s)),
    }
    if cfg.prefix_len:
        batch["patch_embeds"] = jnp.zeros((b, cfg.prefix_len, cfg.d_model),
                                          cfg.dtype)
    if cfg.encoder_layers:
        # nonzero frames so the encoder actually receives gradient signal
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(9), (b, cfg.num_frames, cfg.d_model)
        ).astype(cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_shapes_and_finiteness(arch):
    cfg = registry.get_smoke_config(arch)
    params, _ = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 32
    batch = _batch(cfg, b, s)
    logits, aux, _ = transformer.lm_apply(
        params, cfg, batch["tokens"], batch["positions"],
        patch_embeds=batch.get("patch_embeds"),
        frames=batch.get("frames"))
    expect_s = s + (cfg.prefix_len or 0)
    assert logits.shape == (b, expect_s, cfg.vocab)
    assert np.isfinite(np.asarray(logits, jnp.float32)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_train_step_decreases_nothing_nan(arch):
    cfg = registry.get_smoke_config(arch)
    state = state_lib.init_state(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    step = jax.jit(step_lib.make_train_step(cfg, lr=1e-3))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually changed
    w0 = jax.tree.leaves(state.params)[0]
    w1 = jax.tree.leaves(state2.params)[0]
    assert not np.allclose(np.asarray(w0, jnp.float32),
                           np.asarray(w1, jnp.float32))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_train_step_with_flgw_masked(arch):
    cfg = registry.get_smoke_config(arch).with_updates(
        flgw_groups=4, flgw_path="masked")
    state = state_lib.init_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(step_lib.make_train_step(cfg, lr=1e-3))
    _, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ["gemma2_2b", "mixtral_8x22b",
                                  "mamba2_1_3b"])
def test_smoke_train_step_with_flgw_grouped(arch):
    """The TPU compact path end-to-end inside a real train step."""
    cfg = registry.get_smoke_config(arch).with_updates(
        flgw_groups=4, flgw_path="grouped")
    state = state_lib.init_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(step_lib.make_train_step(cfg, lr=1e-3))
    _, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_decode_step(arch):
    cfg = registry.get_smoke_config(arch)
    params, _ = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    b = 2
    cache = transformer.init_cache(cfg, b, 64)
    if cfg.encoder_layers:
        cache["encoder_out"] = jnp.zeros((b, cfg.num_frames, cfg.d_model),
                                         cfg.dtype)
    serve = jax.jit(make_decode_step(cfg))
    tok = jnp.ones((b, 1), jnp.int32)
    for i in range(3):
        pos = jnp.full((b, 1), i, jnp.int32)
        tok, cache = serve(params, cache, tok, pos)
    assert tok.shape == (b, 1)
    assert int(cache["pos"]) == 3


def test_microbatched_train_step_matches_full_batch_loss():
    cfg = registry.get_smoke_config("gemma2_2b")
    state = state_lib.init_state(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, b=4, s=32)
    s1 = jax.jit(step_lib.make_train_step(cfg, lr=0.0))
    s2 = jax.jit(step_lib.make_train_step(cfg, lr=0.0, microbatches=2))
    _, m1 = s1(state, batch)
    _, m2 = s2(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)


def test_full_configs_match_assignment_table():
    """The exact published dims of every assigned architecture."""
    expect = {
        "gemma2_2b": (26, 2304, 8, 4, 9216, 256000),
        "gemma2_27b": (46, 4608, 32, 16, 36864, 256000),
        "gemma3_12b": (48, 3840, 16, 8, 15360, 262144),
        "internlm2_20b": (48, 6144, 48, 8, 16384, 92544),
        "paligemma_3b": (18, 2048, 8, 1, 16384, 257216),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
        "jamba_1_5_large": (72, 8192, 64, 8, 24576, 65536),
        "mamba2_1_3b": (48, 2048, 0, 0, 0, 50280),
    }
    for arch, (nl, d, h, kv, ff, v) in expect.items():
        cfg = registry.get_config(arch)
        assert cfg.n_layers == nl, arch
        assert cfg.d_model == d, arch
        if h:
            assert cfg.n_heads == h, arch
            assert cfg.n_kv_heads == kv, arch
        if ff:
            assert cfg.d_ff == ff, arch
        assert cfg.vocab == v, arch
    # MoE structure
    assert registry.get_config("mixtral_8x22b").n_experts == 8
    assert registry.get_config("mixtral_8x22b").top_k == 2
    assert registry.get_config("arctic_480b").n_experts == 128
    assert registry.get_config("jamba_1_5_large").n_experts == 16
    assert registry.get_config("mamba2_1_3b").ssm_state == 128
