"""Env registry + per-environment invariants for the multi-scenario layer.

Every registered environment must satisfy the functional ``Env`` protocol:
pure ``reset``/``step`` (identical results under ``jax.jit``), fixed-shape
states that batch under ``jax.vmap``, observation shapes that match
``obs_dim``, and sane reward/termination behaviour. Environment-specific
tests pin the semantics the training engine relies on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.marl import env as legacy_env
from repro.marl import envs
from repro.marl.envs import (predator_prey, spread, traffic_junction,
                             traffic_junction_4way)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_lists_all_bundled_envs():
    assert envs.names() == ["predator_prey", "spread", "traffic_junction",
                            "traffic_junction_4way",
                            "traffic_junction_hard"]


def test_registry_unknown_env_raises_with_candidates():
    with pytest.raises(KeyError, match="predator_prey"):
        envs.get("does_not_exist")


def test_make_applies_config_overrides():
    env, cfg = envs.make("predator_prey", n_agents=5, size=7)
    assert env.config_cls is predator_prey.EnvConfig
    assert cfg.n_agents == 5 and cfg.size == 7


def test_env_records_are_hashable_static_args():
    # the training engine passes Env through jit as a static argument
    assert len({envs.get(n) for n in envs.names()}) == len(envs.names())


def test_legacy_env_module_is_predator_prey():
    """Seed import path must resolve to the same functions as the registry."""
    env = envs.get("predator_prey")
    assert legacy_env.reset is env.reset
    assert legacy_env.step is env.step
    assert legacy_env.observe is env.observe
    assert legacy_env.success is env.success


# ---------------------------------------------------------------------------
# Protocol conformance for every registered env
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", envs.names())
def test_reset_step_observe_shapes(name):
    env, cfg = envs.make(name)
    state = env.reset(jax.random.PRNGKey(0), cfg)
    obs = env.observe(state, cfg)
    assert obs.shape == (cfg.n_agents, env.obs_dim(cfg))
    assert obs.dtype == jnp.float32
    actions = jnp.zeros((cfg.n_agents,), jnp.int32)
    state, rew, done = env.step(state, actions, cfg)
    assert rew.shape == (cfg.n_agents,)
    assert done.shape == () and done.dtype == bool
    assert env.success(state).dtype == bool
    assert env.n_actions(cfg) >= 2


@pytest.mark.parametrize("name", envs.names())
def test_step_is_pure_under_jit(name):
    env, cfg = envs.make(name)
    key = jax.random.PRNGKey(1)
    state = env.reset(key, cfg)
    actions = jax.random.randint(key, (cfg.n_agents,), 0,
                                 env.n_actions(cfg))
    eager = env.step(state, actions, cfg)
    jitted = jax.jit(env.step, static_argnums=2)(state, actions, cfg)
    for a, b in zip(jax.tree.leaves(eager), jax.tree.leaves(jitted)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", envs.names())
def test_reset_and_step_batch_under_vmap(name):
    env, cfg = envs.make(name)
    b = 8
    keys = jax.random.split(jax.random.PRNGKey(2), b)
    states = jax.vmap(lambda k: env.reset(k, cfg))(keys)
    obs = jax.vmap(lambda s: env.observe(s, cfg))(states)
    assert obs.shape == (b, cfg.n_agents, env.obs_dim(cfg))
    actions = jnp.zeros((b, cfg.n_agents), jnp.int32)
    _, rew, done = jax.vmap(lambda s, a: env.step(s, a, cfg))(states,
                                                             actions)
    assert rew.shape == (b, cfg.n_agents) and done.shape == (b,)


@pytest.mark.parametrize("name", envs.names())
def test_episode_terminates_at_max_steps(name):
    env, cfg = envs.make(name)
    key = jax.random.PRNGKey(3)
    state = env.reset(key, cfg)
    done = jnp.zeros((), bool)
    for i in range(cfg.max_steps):
        k = jax.random.fold_in(key, i)
        actions = jax.random.randint(k, (cfg.n_agents,), 0,
                                     env.n_actions(cfg))
        state, _, done = env.step(state, actions, cfg)
    assert bool(done)


# ---------------------------------------------------------------------------
# Traffic Junction semantics
# ---------------------------------------------------------------------------

def test_tj_entries_are_distinct_and_progress_monotonic():
    cfg = traffic_junction.EnvConfig(n_agents=5, size=7, max_steps=30)
    state = traffic_junction.reset(jax.random.PRNGKey(0), cfg)
    assert sorted(np.asarray(state.enter_t).tolist()) == list(range(5))
    prev = np.asarray(state.prog)
    for _ in range(10):
        state, _, _ = traffic_junction.step(
            state, jnp.ones((5,), jnp.int32), cfg)
        cur = np.asarray(state.prog)
        assert (cur >= prev).all() and (cur <= cfg.size).all()
        prev = cur


def test_tj_same_route_full_speed_never_collides():
    """Distinct entries + everyone gassing on one road ⇒ no collision."""
    cfg = traffic_junction.EnvConfig(n_agents=4, size=7, max_steps=30)
    state = traffic_junction.reset(jax.random.PRNGKey(0), cfg)
    state = state._replace(route=jnp.zeros((4,), jnp.int32))
    for _ in range(cfg.max_steps):
        state, _, done = traffic_junction.step(
            state, jnp.ones((4,), jnp.int32), cfg)
    assert bool(traffic_junction.success(state))
    assert bool(done)


def test_tj_shared_cell_collides_and_sinks_success():
    cfg = traffic_junction.EnvConfig(n_agents=2, size=7, max_steps=30)
    # both cars active on route 0, car 1 right behind car 0
    state = traffic_junction.EnvState(
        route=jnp.zeros((2,), jnp.int32),
        enter_t=jnp.zeros((2,), jnp.int32),
        prog=jnp.array([1, 0], jnp.int32),
        collided=jnp.zeros((), bool),
        cleared=jnp.zeros((), bool),
        t=jnp.ones((), jnp.int32))
    # car 0 brakes, car 1 gasses into it
    state, rew, _ = traffic_junction.step(
        state, jnp.array([0, 1], jnp.int32), cfg)
    assert bool(state.collided)
    assert not bool(traffic_junction.success(state))
    assert float(rew[0]) < 0 and float(rew[1]) < 0


def test_tj_spawning_onto_occupied_entry_cell_collides():
    cfg = traffic_junction.EnvConfig(n_agents=2, size=7, max_steps=30)
    state = traffic_junction.EnvState(
        route=jnp.zeros((2,), jnp.int32),
        enter_t=jnp.array([0, 1], jnp.int32),
        prog=jnp.zeros((2,), jnp.int32),
        collided=jnp.zeros((), bool),
        cleared=jnp.zeros((), bool),
        t=jnp.zeros((), jnp.int32))
    # car 0 brakes on its entry cell during the step in which car 1 enters
    state, _, _ = traffic_junction.step(
        state, jnp.zeros((2,), jnp.int32), cfg)
    assert bool(state.collided)


def test_tj_all_brake_policy_is_not_a_success():
    """Waiting out the episode collision-free must not count as success —
    every car has to actually clear the grid."""
    cfg = traffic_junction.EnvConfig(n_agents=2, size=7, max_steps=6)
    state = traffic_junction.reset(jax.random.PRNGKey(0), cfg)
    # put the cars on different roads so braking forever cannot collide
    state = state._replace(route=jnp.array([0, 1], jnp.int32))
    for _ in range(cfg.max_steps):
        state, _, done = traffic_junction.step(
            state, jnp.zeros((2,), jnp.int32), cfg)
    assert bool(done)
    assert not bool(state.collided)
    assert not bool(traffic_junction.success(state))


def test_tj_hard_arrivals_are_denser_and_entries_feasible():
    """Hard variant: Geometric(p_arrive) arrival stream — entry times are
    strictly increasing, start at 0, and every car can still clear the grid
    before max_steps; higher p_arrive must not *spread out* the entries
    relative to the easy one-per-step staggering."""
    cfg = traffic_junction.HardConfig(n_agents=8, p_arrive=0.9)
    state = traffic_junction.reset_hard(jax.random.PRNGKey(0), cfg)
    enter = np.asarray(state.enter_t)
    assert enter[0] == 0
    assert (np.diff(enter) >= 1).all()
    assert enter.max() <= cfg.max_steps - cfg.size - 1
    # p→1 degenerates to the easy env's one-car-per-step staggering
    dense = traffic_junction.reset_hard(
        jax.random.PRNGKey(0), cfg._replace(p_arrive=1.0))
    np.testing.assert_array_equal(np.sort(np.asarray(dense.enter_t)),
                                  np.arange(cfg.n_agents))
    # low p_arrive: the feasibility squeeze must keep entries strictly
    # increasing (shared entry steps would spawn unavoidable collisions)
    for seed in range(8):
        sparse = traffic_junction.reset_hard(
            jax.random.PRNGKey(seed), cfg._replace(p_arrive=0.05))
        e = np.asarray(sparse.enter_t)
        assert (np.diff(e) >= 1).all(), e
        assert e.max() <= cfg.max_steps - cfg.size - 1


def test_tj_inactive_cars_get_zero_reward():
    cfg = traffic_junction.EnvConfig(n_agents=3, size=7, max_steps=30)
    state = traffic_junction.reset(jax.random.PRNGKey(1), cfg)
    # latest entrant is still off-road at t=0
    late = int(np.asarray(state.enter_t).argmax())
    _, rew, _ = traffic_junction.step(state, jnp.ones((3,), jnp.int32), cfg)
    assert float(rew[late]) == 0.0


# ---------------------------------------------------------------------------
# 4-way Traffic Junction semantics
# ---------------------------------------------------------------------------

def test_tj4_route_table_geometry():
    """All 12 routes: in-bounds, unit-step-connected, lane-respecting,
    boundary-to-boundary, and mutually distinct."""
    s = 8
    m = s // 2
    table, lens = traffic_junction_4way._route_table(s)
    assert table.shape == (12, s + 1, 2)
    assert lens.min() == s - 1 and lens.max() == s + 1   # right < str < left
    seen = set()
    for r in range(12):
        path = table[r, :lens[r]]
        assert (path >= 0).all() and (path < s).all(), r
        # consecutive cells are grid-adjacent (the car moves one cell/step)
        assert (np.abs(np.diff(path, axis=0)).sum(axis=1) == 1).all(), r
        # entry and exit on the grid boundary
        assert path[0].min() == 0 or path[0].max() == s - 1, r
        assert path[-1].min() == 0 or path[-1].max() == s - 1, r
        # every cell sits on one of the four lanes
        assert ((path[:, 0] == m) | (path[:, 0] == m - 1)
                | (path[:, 1] == m) | (path[:, 1] == m - 1)).all(), r
        seen.add(tuple(map(tuple, path)))
        # padding slots repeat the exit cell (safe to clip prog into)
        np.testing.assert_array_equal(table[r, lens[r]:],
                                      np.broadcast_to(path[-1],
                                                      (s + 1 - lens[r], 2)))
    assert len(seen) == 12
    # the four straight routes are the full-length lane traversals
    for arm in range(4):
        assert lens[arm * 3 + 1] == s


def test_tj4_entries_feasible_and_routes_in_range():
    cfg = traffic_junction_4way.EnvConfig(n_agents=8, p_arrive=0.9)
    state = traffic_junction_4way.reset(jax.random.PRNGKey(0), cfg)
    enter = np.asarray(state.enter_t)
    route = np.asarray(state.route)
    assert enter[0] == 0
    assert (np.diff(enter) >= 1).all()
    # every car can still clear its longest-possible route before max_steps
    assert enter.max() <= cfg.max_steps - (cfg.size + 1) - 1
    assert (0 <= route).all() and (route < traffic_junction_4way.N_ROUTES).all()


def test_tj4_single_car_full_speed_clears_every_route():
    cfg = traffic_junction_4way.EnvConfig(n_agents=1, size=8, max_steps=20)
    for r in range(traffic_junction_4way.N_ROUTES):
        state = traffic_junction_4way.reset(jax.random.PRNGKey(0), cfg)
        state = state._replace(route=jnp.array([r], jnp.int32),
                               enter_t=jnp.zeros((1,), jnp.int32))
        done = jnp.zeros((), bool)
        for _ in range(cfg.max_steps):
            state, _, done = traffic_junction_4way.step(
                state, jnp.ones((1,), jnp.int32), cfg)
        assert bool(traffic_junction_4way.success(state)), r
        assert bool(done), r


def test_tj4_crossing_straights_collide_at_junction():
    """An eastbound and a southbound car that both gas through the
    intersection at the same time must collide on the shared cell."""
    cfg = traffic_junction_4way.EnvConfig(n_agents=2, size=8, max_steps=40)
    # route 1 = west arm straight (row m, cell (m, m-1) at index m-1);
    # route 4 = north arm straight (col m-1, cell (m, m-1) at index m) —
    # entering one step apart puts both on (m, m-1) at the same time
    state = traffic_junction_4way.EnvState(
        route=jnp.array([1, 4], jnp.int32),
        enter_t=jnp.array([1, 0], jnp.int32),
        prog=jnp.zeros((2,), jnp.int32),
        collided=jnp.zeros((), bool),
        cleared=jnp.zeros((), bool),
        t=jnp.zeros((), jnp.int32))
    collided = False
    for _ in range(cfg.max_steps):
        state, _, done = traffic_junction_4way.step(
            state, jnp.ones((2,), jnp.int32), cfg)
        collided = collided or bool(state.collided)
        if bool(done):
            break
    assert collided
    assert not bool(traffic_junction_4way.success(state))


def test_tj4_braking_avoids_the_crossing_collision():
    """Same geometry as above, but the eastbound car yields one step at
    the junction mouth — the coordination communication must learn."""
    cfg = traffic_junction_4way.EnvConfig(n_agents=2, size=8, max_steps=40)
    state = traffic_junction_4way.EnvState(
        route=jnp.array([1, 4], jnp.int32),
        enter_t=jnp.array([1, 0], jnp.int32),
        prog=jnp.zeros((2,), jnp.int32),
        collided=jnp.zeros((), bool),
        cleared=jnp.zeros((), bool),
        t=jnp.zeros((), jnp.int32))
    for i in range(cfg.max_steps):
        a0 = 0 if i == 3 else 1      # yield exactly once before the junction
        state, _, done = traffic_junction_4way.step(
            state, jnp.array([a0, 1], jnp.int32), cfg)
        if bool(done):
            break
    assert not bool(state.collided)
    assert bool(traffic_junction_4way.success(state))


def test_tj4_odd_size_rejected():
    with pytest.raises(ValueError, match="even"):
        traffic_junction_4way._route_table(7)


# ---------------------------------------------------------------------------
# Spread semantics
# ---------------------------------------------------------------------------

def test_spread_success_iff_all_landmarks_covered():
    cfg = spread.EnvConfig(n_agents=3, size=5)
    lms = jnp.array([[0, 0], [2, 2], [4, 4]], jnp.int32)
    on = spread.EnvState(pos=lms, landmarks=lms, t=jnp.zeros((), jnp.int32))
    assert bool(spread.success(on))
    off = on._replace(pos=lms.at[0, 0].set(1))
    assert not bool(spread.success(off))


def test_spread_coverage_improves_reward():
    cfg = spread.EnvConfig(n_agents=2, size=5)
    lms = jnp.array([[0, 0], [4, 4]], jnp.int32)
    near = spread.EnvState(pos=jnp.array([[0, 1], [4, 3]], jnp.int32),
                           landmarks=lms, t=jnp.zeros((), jnp.int32))
    far = near._replace(pos=jnp.array([[2, 2], [2, 2]], jnp.int32))
    # stepping "stay" from the near config must beat the far config
    _, r_near, _ = spread.step(near, jnp.zeros((2,), jnp.int32), cfg)
    _, r_far, _ = spread.step(far, jnp.zeros((2,), jnp.int32), cfg)
    assert float(jnp.mean(r_near)) > float(jnp.mean(r_far))


def test_spread_positions_stay_in_bounds():
    cfg = spread.EnvConfig(n_agents=3, size=4, max_steps=12)
    key = jax.random.PRNGKey(4)
    state = spread.reset(key, cfg)
    for i in range(cfg.max_steps):
        k = jax.random.fold_in(key, i)
        actions = jax.random.randint(k, (3,), 0, spread.N_ACTIONS)
        state, _, _ = spread.step(state, actions, cfg)
        pos = np.asarray(state.pos)
        assert (pos >= 0).all() and (pos < cfg.size).all()


def test_spread_done_when_covered():
    cfg = spread.EnvConfig(n_agents=2, size=5)
    lms = jnp.array([[1, 1], [3, 3]], jnp.int32)
    state = spread.EnvState(pos=jnp.array([[1, 1], [3, 2]], jnp.int32),
                            landmarks=lms, t=jnp.zeros((), jnp.int32))
    state, _, done = spread.step(state, jnp.array([0, 4], jnp.int32), cfg)
    assert bool(done) and bool(spread.success(state))
