"""Env registry + per-environment invariants for the multi-scenario layer.

Every registered environment must satisfy the functional ``Env`` protocol:
pure ``reset``/``step`` (identical results under ``jax.jit``), fixed-shape
states that batch under ``jax.vmap``, observation shapes that match
``obs_dim``, and sane reward/termination behaviour. Environment-specific
tests pin the semantics the training engine relies on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.marl import env as legacy_env
from repro.marl import envs
from repro.marl.envs import predator_prey, spread, traffic_junction


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_lists_all_bundled_envs():
    assert envs.names() == ["predator_prey", "spread", "traffic_junction",
                            "traffic_junction_hard"]


def test_registry_unknown_env_raises_with_candidates():
    with pytest.raises(KeyError, match="predator_prey"):
        envs.get("does_not_exist")


def test_make_applies_config_overrides():
    env, cfg = envs.make("predator_prey", n_agents=5, size=7)
    assert env.config_cls is predator_prey.EnvConfig
    assert cfg.n_agents == 5 and cfg.size == 7


def test_env_records_are_hashable_static_args():
    # the training engine passes Env through jit as a static argument
    assert len({envs.get(n) for n in envs.names()}) == len(envs.names())


def test_legacy_env_module_is_predator_prey():
    """Seed import path must resolve to the same functions as the registry."""
    env = envs.get("predator_prey")
    assert legacy_env.reset is env.reset
    assert legacy_env.step is env.step
    assert legacy_env.observe is env.observe
    assert legacy_env.success is env.success


# ---------------------------------------------------------------------------
# Protocol conformance for every registered env
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", envs.names())
def test_reset_step_observe_shapes(name):
    env, cfg = envs.make(name)
    state = env.reset(jax.random.PRNGKey(0), cfg)
    obs = env.observe(state, cfg)
    assert obs.shape == (cfg.n_agents, env.obs_dim(cfg))
    assert obs.dtype == jnp.float32
    actions = jnp.zeros((cfg.n_agents,), jnp.int32)
    state, rew, done = env.step(state, actions, cfg)
    assert rew.shape == (cfg.n_agents,)
    assert done.shape == () and done.dtype == bool
    assert env.success(state).dtype == bool
    assert env.n_actions(cfg) >= 2


@pytest.mark.parametrize("name", envs.names())
def test_step_is_pure_under_jit(name):
    env, cfg = envs.make(name)
    key = jax.random.PRNGKey(1)
    state = env.reset(key, cfg)
    actions = jax.random.randint(key, (cfg.n_agents,), 0,
                                 env.n_actions(cfg))
    eager = env.step(state, actions, cfg)
    jitted = jax.jit(env.step, static_argnums=2)(state, actions, cfg)
    for a, b in zip(jax.tree.leaves(eager), jax.tree.leaves(jitted)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", envs.names())
def test_reset_and_step_batch_under_vmap(name):
    env, cfg = envs.make(name)
    b = 8
    keys = jax.random.split(jax.random.PRNGKey(2), b)
    states = jax.vmap(lambda k: env.reset(k, cfg))(keys)
    obs = jax.vmap(lambda s: env.observe(s, cfg))(states)
    assert obs.shape == (b, cfg.n_agents, env.obs_dim(cfg))
    actions = jnp.zeros((b, cfg.n_agents), jnp.int32)
    _, rew, done = jax.vmap(lambda s, a: env.step(s, a, cfg))(states,
                                                             actions)
    assert rew.shape == (b, cfg.n_agents) and done.shape == (b,)


@pytest.mark.parametrize("name", envs.names())
def test_episode_terminates_at_max_steps(name):
    env, cfg = envs.make(name)
    key = jax.random.PRNGKey(3)
    state = env.reset(key, cfg)
    done = jnp.zeros((), bool)
    for i in range(cfg.max_steps):
        k = jax.random.fold_in(key, i)
        actions = jax.random.randint(k, (cfg.n_agents,), 0,
                                     env.n_actions(cfg))
        state, _, done = env.step(state, actions, cfg)
    assert bool(done)


# ---------------------------------------------------------------------------
# Traffic Junction semantics
# ---------------------------------------------------------------------------

def test_tj_entries_are_distinct_and_progress_monotonic():
    cfg = traffic_junction.EnvConfig(n_agents=5, size=7, max_steps=30)
    state = traffic_junction.reset(jax.random.PRNGKey(0), cfg)
    assert sorted(np.asarray(state.enter_t).tolist()) == list(range(5))
    prev = np.asarray(state.prog)
    for _ in range(10):
        state, _, _ = traffic_junction.step(
            state, jnp.ones((5,), jnp.int32), cfg)
        cur = np.asarray(state.prog)
        assert (cur >= prev).all() and (cur <= cfg.size).all()
        prev = cur


def test_tj_same_route_full_speed_never_collides():
    """Distinct entries + everyone gassing on one road ⇒ no collision."""
    cfg = traffic_junction.EnvConfig(n_agents=4, size=7, max_steps=30)
    state = traffic_junction.reset(jax.random.PRNGKey(0), cfg)
    state = state._replace(route=jnp.zeros((4,), jnp.int32))
    for _ in range(cfg.max_steps):
        state, _, done = traffic_junction.step(
            state, jnp.ones((4,), jnp.int32), cfg)
    assert bool(traffic_junction.success(state))
    assert bool(done)


def test_tj_shared_cell_collides_and_sinks_success():
    cfg = traffic_junction.EnvConfig(n_agents=2, size=7, max_steps=30)
    # both cars active on route 0, car 1 right behind car 0
    state = traffic_junction.EnvState(
        route=jnp.zeros((2,), jnp.int32),
        enter_t=jnp.zeros((2,), jnp.int32),
        prog=jnp.array([1, 0], jnp.int32),
        collided=jnp.zeros((), bool),
        cleared=jnp.zeros((), bool),
        t=jnp.ones((), jnp.int32))
    # car 0 brakes, car 1 gasses into it
    state, rew, _ = traffic_junction.step(
        state, jnp.array([0, 1], jnp.int32), cfg)
    assert bool(state.collided)
    assert not bool(traffic_junction.success(state))
    assert float(rew[0]) < 0 and float(rew[1]) < 0


def test_tj_spawning_onto_occupied_entry_cell_collides():
    cfg = traffic_junction.EnvConfig(n_agents=2, size=7, max_steps=30)
    state = traffic_junction.EnvState(
        route=jnp.zeros((2,), jnp.int32),
        enter_t=jnp.array([0, 1], jnp.int32),
        prog=jnp.zeros((2,), jnp.int32),
        collided=jnp.zeros((), bool),
        cleared=jnp.zeros((), bool),
        t=jnp.zeros((), jnp.int32))
    # car 0 brakes on its entry cell during the step in which car 1 enters
    state, _, _ = traffic_junction.step(
        state, jnp.zeros((2,), jnp.int32), cfg)
    assert bool(state.collided)


def test_tj_all_brake_policy_is_not_a_success():
    """Waiting out the episode collision-free must not count as success —
    every car has to actually clear the grid."""
    cfg = traffic_junction.EnvConfig(n_agents=2, size=7, max_steps=6)
    state = traffic_junction.reset(jax.random.PRNGKey(0), cfg)
    # put the cars on different roads so braking forever cannot collide
    state = state._replace(route=jnp.array([0, 1], jnp.int32))
    for _ in range(cfg.max_steps):
        state, _, done = traffic_junction.step(
            state, jnp.zeros((2,), jnp.int32), cfg)
    assert bool(done)
    assert not bool(state.collided)
    assert not bool(traffic_junction.success(state))


def test_tj_hard_arrivals_are_denser_and_entries_feasible():
    """Hard variant: Geometric(p_arrive) arrival stream — entry times are
    strictly increasing, start at 0, and every car can still clear the grid
    before max_steps; higher p_arrive must not *spread out* the entries
    relative to the easy one-per-step staggering."""
    cfg = traffic_junction.HardConfig(n_agents=8, p_arrive=0.9)
    state = traffic_junction.reset_hard(jax.random.PRNGKey(0), cfg)
    enter = np.asarray(state.enter_t)
    assert enter[0] == 0
    assert (np.diff(enter) >= 1).all()
    assert enter.max() <= cfg.max_steps - cfg.size - 1
    # p→1 degenerates to the easy env's one-car-per-step staggering
    dense = traffic_junction.reset_hard(
        jax.random.PRNGKey(0), cfg._replace(p_arrive=1.0))
    np.testing.assert_array_equal(np.sort(np.asarray(dense.enter_t)),
                                  np.arange(cfg.n_agents))
    # low p_arrive: the feasibility squeeze must keep entries strictly
    # increasing (shared entry steps would spawn unavoidable collisions)
    for seed in range(8):
        sparse = traffic_junction.reset_hard(
            jax.random.PRNGKey(seed), cfg._replace(p_arrive=0.05))
        e = np.asarray(sparse.enter_t)
        assert (np.diff(e) >= 1).all(), e
        assert e.max() <= cfg.max_steps - cfg.size - 1


def test_tj_inactive_cars_get_zero_reward():
    cfg = traffic_junction.EnvConfig(n_agents=3, size=7, max_steps=30)
    state = traffic_junction.reset(jax.random.PRNGKey(1), cfg)
    # latest entrant is still off-road at t=0
    late = int(np.asarray(state.enter_t).argmax())
    _, rew, _ = traffic_junction.step(state, jnp.ones((3,), jnp.int32), cfg)
    assert float(rew[late]) == 0.0


# ---------------------------------------------------------------------------
# Spread semantics
# ---------------------------------------------------------------------------

def test_spread_success_iff_all_landmarks_covered():
    cfg = spread.EnvConfig(n_agents=3, size=5)
    lms = jnp.array([[0, 0], [2, 2], [4, 4]], jnp.int32)
    on = spread.EnvState(pos=lms, landmarks=lms, t=jnp.zeros((), jnp.int32))
    assert bool(spread.success(on))
    off = on._replace(pos=lms.at[0, 0].set(1))
    assert not bool(spread.success(off))


def test_spread_coverage_improves_reward():
    cfg = spread.EnvConfig(n_agents=2, size=5)
    lms = jnp.array([[0, 0], [4, 4]], jnp.int32)
    near = spread.EnvState(pos=jnp.array([[0, 1], [4, 3]], jnp.int32),
                           landmarks=lms, t=jnp.zeros((), jnp.int32))
    far = near._replace(pos=jnp.array([[2, 2], [2, 2]], jnp.int32))
    # stepping "stay" from the near config must beat the far config
    _, r_near, _ = spread.step(near, jnp.zeros((2,), jnp.int32), cfg)
    _, r_far, _ = spread.step(far, jnp.zeros((2,), jnp.int32), cfg)
    assert float(jnp.mean(r_near)) > float(jnp.mean(r_far))


def test_spread_positions_stay_in_bounds():
    cfg = spread.EnvConfig(n_agents=3, size=4, max_steps=12)
    key = jax.random.PRNGKey(4)
    state = spread.reset(key, cfg)
    for i in range(cfg.max_steps):
        k = jax.random.fold_in(key, i)
        actions = jax.random.randint(k, (3,), 0, spread.N_ACTIONS)
        state, _, _ = spread.step(state, actions, cfg)
        pos = np.asarray(state.pos)
        assert (pos >= 0).all() and (pos < cfg.size).all()


def test_spread_done_when_covered():
    cfg = spread.EnvConfig(n_agents=2, size=5)
    lms = jnp.array([[1, 1], [3, 3]], jnp.int32)
    state = spread.EnvState(pos=jnp.array([[1, 1], [3, 2]], jnp.int32),
                            landmarks=lms, t=jnp.zeros((), jnp.int32))
    state, _, done = spread.step(state, jnp.array([0, 4], jnp.int32), cfg)
    assert bool(done) and bool(spread.success(state))
