"""The static grid/BlockSpec auditor (``repro.analysis.kernel_audit``).

Three layers under test:

* the checker itself — deliberately broken :class:`GridCase` fixtures,
  one per check class (out-of-bounds origin, output coverage gap,
  undeclared overlapping writes, non-consecutive accumulation revisit,
  VMEM blowout), each pinned to fire exactly its finding;
* the shipped registry — every ``pallas_call`` module in ``src`` has a
  registered :class:`KernelSpec` naming it, the whole corpus audits
  clean at the default budget, and the corpus genuinely covers the
  M > 4096 and slack > 1 geometries the PR-7 cap-lift introduced;
* the toolchain contract — the registry loads without importing jax
  (the CI analysis job runs jax-free) and the CLI exit codes gate.

No jax import in this file: the auditor must stay importable and
correct with nothing but the standard library.
"""
import ast
import os
import subprocess
import sys

from repro.analysis.kernel_audit import (AUDIT_MODULES,
                                         DEFAULT_VMEM_BUDGET, GridCase,
                                         Operand, audit_all, audit_case,
                                         case_vmem_bytes, corpus_tags,
                                         load_registry, main, vmem_table)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def _codes(report):
    return sorted({f.check for f in report.findings})


# -- broken fixtures: each check class fires ---------------------------------

def test_out_of_bounds_origin_fires_bounds():
    # grid point 1 places the (8, 8) block at origin (8, 0) in an
    # (8, 8) operand — one bounds finding, nothing else
    case = GridCase(
        label="oob", grid=(2,),
        operands=(
            Operand("x", (8, 8), (8, 8), lambda i: (i, 0)),
        ))
    rep = audit_case("fixture", case)
    assert _codes(rep) == ["bounds"]
    assert len(rep.findings) == 1
    assert "origin (8, 0)" in rep.findings[0].message


def test_index_map_rank_mismatch_fires_bounds():
    case = GridCase(
        label="rank", grid=(2,),
        operands=(
            Operand("x", (16, 8), (8, 8), lambda i: (i,)),
        ))
    rep = audit_case("fixture", case)
    assert _codes(rep) == ["bounds"]
    assert "block indices" in rep.findings[0].message


def test_coverage_gap_fires_coverage():
    # 4 output tiles, the grid only ever writes column 0 — 2 never
    # written. The flash_bwd non-dividing-block failure shape.
    case = GridCase(
        label="gap", grid=(2,),
        operands=(
            Operand("y", (16, 16), (8, 8), lambda i: (i, 0),
                    role="out"),
        ))
    rep = audit_case("fixture", case)
    assert _codes(rep) == ["coverage"]
    assert len(rep.findings) == 1
    assert "2 of 4" in rep.findings[0].message


def test_undeclared_overlapping_writes_fire_disjoint():
    # grid (2, 2) collapses axis 1 onto the same output tile with no
    # accum declaration — a write race
    case = GridCase(
        label="race", grid=(2, 2),
        operands=(
            Operand("y", (16, 8), (8, 8), lambda i, j: (i, 0),
                    role="out"),
        ))
    rep = audit_case("fixture", case)
    assert _codes(rep) == ["disjoint"]
    assert "undeclared" in rep.findings[0].message
    # declaring the axis as accumulation makes the same case legal:
    # revisits are consecutive (axis 1 is innermost)
    fixed = GridCase(
        label="accum", grid=(2, 2),
        operands=case.operands, accum_axes=frozenset({1}))
    assert audit_case("fixture", fixed).ok


def test_non_consecutive_revisit_fires_disjoint():
    # axis 0 is declared accumulation, but it is the OUTER axis: tile
    # (0, 0) is revisited at grid steps 0 and 2 with step 1 in between
    # — Mosaic would flush the accumulator mid-reduction
    case = GridCase(
        label="flush", grid=(2, 2),
        operands=(
            Operand("y", (16, 8), (8, 8), lambda i, j: (j, 0),
                    role="out"),
        ),
        accum_axes=frozenset({0}))
    rep = audit_case("fixture", case)
    assert _codes(rep) == ["disjoint"]
    assert "non-consecutive" in rep.findings[0].message


def test_vmem_blowout_fires_vmem():
    # one (4096, 4096) f32 block = 64 MiB > the 16 MiB default budget
    case = GridCase(
        label="blowout", grid=(1,),
        operands=(
            Operand("x", (4096, 4096), (4096, 4096),
                    lambda i: (0, 0)),
        ))
    assert case_vmem_bytes(case) == 4096 * 4096 * 4
    rep = audit_case("fixture", case)
    assert _codes(rep) == ["vmem"]
    # a budget that fits turns it green
    assert audit_case("fixture", case, budget=128 * 2**20).ok


def test_scratch_counts_toward_vmem():
    lean = GridCase(label="s", grid=(1,),
                    operands=(Operand("x", (8, 8), (8, 8),
                                      lambda i: (0, 0)),))
    fat = GridCase(label="s", grid=(1,), operands=lean.operands,
                   scratch_bytes=1024)
    assert case_vmem_bytes(fat) == case_vmem_bytes(lean) + 1024


# -- the shipped registry audits clean ---------------------------------------

def test_repo_audits_clean_at_default_budget():
    reports = audit_all()
    bad = [f.render() for r in reports for f in r.findings]
    assert bad == [], bad
    # every report fits the conservative 16 MiB budget with headroom
    assert all(r.vmem_bytes <= DEFAULT_VMEM_BUDGET for r in reports)


def test_corpus_covers_cap_lift_geometries():
    tags = corpus_tags()
    assert "m_gt_4096" in tags       # PR-7 lifted the 4096-item cap
    assert "slack_gt_1" in tags      # capacity-stretch grouping
    # M > 4096 is proven for every kernel family, not just one
    by_family = {}
    for r in audit_all():
        fam = r.kernel.split(".")[0]
        by_family.setdefault(fam, set()).update(r.tags)
    assert set(by_family) == {"flash_attention", "flgw_matmul",
                              "osel_encode", "plan_encode"}
    for fam, tags in by_family.items():
        assert "m_gt_4096" in tags, fam


def test_every_pallas_call_module_has_a_registered_spec():
    """The ANL006 invariant, enforced structurally: each src module
    containing a pallas_call appears as some KernelSpec's ``module``."""
    registered = {spec.module for spec in load_registry().values()}
    pallas_modules = set()
    for dirpath, _, filenames in os.walk(os.path.join(SRC, "repro")):
        for fn in filenames:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path, "r", encoding="utf-8") as fh:
                src = fh.read()
            if "pallas_call" not in src:
                continue
            tree = ast.parse(src)
            calls = [n for n in ast.walk(tree)
                     if isinstance(n, ast.Call)
                     and isinstance(n.func, ast.Attribute)
                     and n.func.attr == "pallas_call"]
            if calls:
                rel = os.path.relpath(path, SRC)
                pallas_modules.add(
                    rel[:-3].replace(os.sep, "."))
    assert pallas_modules, "no pallas_call modules found under src"
    missing = pallas_modules - registered
    assert missing == set(), missing


def test_vmem_table_shape():
    table = vmem_table()
    assert set(table) == {k for k in load_registry()}
    for kernel, cases in table.items():
        for case, row in cases.items():
            assert row["ok"] is True
            assert row["vmem_bytes"] > 0
            assert row["grid_points"] >= 1


# -- toolchain contract: jax-free, CLI gates ---------------------------------

def test_registry_loads_without_jax():
    """The CI analysis job has no jax; loading every KernelSpec and
    auditing the corpus must never import it."""
    code = (
        "import sys\n"
        "from repro.analysis.kernel_audit import audit_all\n"
        "reports = audit_all()\n"
        "assert reports and all(r.ok for r in reports)\n"
        "assert 'jax' not in sys.modules, 'jax leaked into the audit'\n"
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    subprocess.run([sys.executable, "-c", code], check=True, env=env,
                   cwd=REPO)


def test_audit_modules_list_is_complete():
    assert len(AUDIT_MODULES) == 4
    assert {m.split(".")[2] for m in AUDIT_MODULES} == {
        "flash_attention", "flgw_matmul", "osel_encode", "plan_encode"}


def test_cli_check_exit_codes(capsys):
    assert main(["--check"]) == 0
    # a starvation budget turns every case red
    assert main(["--check", "--budget-mib", "0.001"]) == 1
    # an unknown kernel filter is an error, not a silent green
    assert main(["--kernel", "no_such_kernel"]) == 1
    out = capsys.readouterr()
    assert "audit clean" in out.out


def test_cli_json_dump(tmp_path, capsys):
    import json
    dest = tmp_path / "audit.json"
    assert main(["--json", str(dest)]) == 0
    doc = json.loads(dest.read_text())
    assert "flgw_matmul.grouped_bmm" in doc
    capsys.readouterr()
