"""FLGW algorithm invariants (paper §III-A / OSEL observations 1–2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import flgw
from repro.core.osel import encode, mask_from_memory, transpose_encode


def _rand_grouping(key, m, n, g):
    ig = jax.random.normal(key, (m, g))
    og = jax.random.normal(jax.random.fold_in(key, 1), (g, n))
    return ig, og


@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 48), n=st.integers(2, 48), g=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_mask_equals_is_os_product(m, n, g, seed):
    """OSEL observation 1: index-equality mask == IS @ OS (paper's def)."""
    ig, og = _rand_grouping(jax.random.PRNGKey(seed), m, n, g)
    ig_idx, og_idx = flgw.grouping_indices(ig, og)
    fast = flgw.mask_from_indices(ig_idx, og_idx)
    is_mat = jax.nn.one_hot(jnp.argmax(ig, 1), g)
    os_mat = jax.nn.one_hot(jnp.argmax(og, 0), g, axis=0)
    slow = (is_mat @ os_mat) > 0.5
    np.testing.assert_array_equal(np.asarray(fast) > 0.5, np.asarray(slow))


@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 64), n=st.integers(2, 64), g=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_mask_has_at_most_g_distinct_rows(m, n, g, seed):
    """OSEL observation 2: rows of the mask are rows of OS — ≤ G distinct."""
    ig, og = _rand_grouping(jax.random.PRNGKey(seed), m, n, g)
    ig_idx, og_idx = flgw.grouping_indices(ig, og)
    mask = np.asarray(flgw.mask_from_indices(ig_idx, og_idx))
    distinct = {tuple(row) for row in mask}
    assert len(distinct) <= g


@settings(max_examples=25, deadline=None)
@given(m=st.integers(4, 64), n=st.integers(4, 64),
       g=st.sampled_from([1, 2, 4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_mask_sparsity_formula(m, n, g, seed):
    """mask_sparsity (from the two histograms) == sparsity of the mask."""
    ig, og = _rand_grouping(jax.random.PRNGKey(seed), m, n, g)
    ig_idx, og_idx = flgw.grouping_indices(ig, og)
    mask = np.asarray(flgw.mask_from_indices(ig_idx, og_idx))
    got = float(flgw.mask_sparsity(ig_idx, og_idx, groups=g))
    want = 1.0 - mask.mean()
    assert got == pytest.approx(want, abs=1e-6)


def test_expected_sparsity_converges_to_one_minus_inv_g():
    """Paper: average sparsity = 1 − 1/G (random init). G=128 guards the
    old silent ``groups=64`` default, whose truncated histograms made the
    formula lie for G > 64 (mask_sparsity now requires G)."""
    key = jax.random.PRNGKey(0)
    for g in (2, 4, 8, 16, 128):
        ig, og = _rand_grouping(key, 512, 512, g)
        ig_idx, og_idx = flgw.grouping_indices(ig, og)
        s = float(flgw.mask_sparsity(ig_idx, og_idx, groups=g))
        assert s == pytest.approx(1.0 - 1.0 / g, abs=0.08)


def test_masked_weights_preserved_not_removed():
    """FLGW masks weights rather than zeroing them: W is untouched, only
    the product sees the mask (paper: masked weights usable next iter)."""
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (8, 8))
    ig, og = _rand_grouping(key, 8, 8, 4)
    cfg = flgw.FLGWConfig(groups=4, path="masked")
    x = jax.random.normal(jax.random.fold_in(key, 2), (3, 8))
    y = flgw.flgw_linear(x, w, ig, og, cfg)
    ig_idx, og_idx = flgw.grouping_indices(ig, og)
    mask = flgw.mask_from_indices(ig_idx, og_idx)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ (w * mask)),
                               rtol=1e-5, atol=1e-5)


def test_ste_gradients_flow_to_grouping_matrices():
    key = jax.random.PRNGKey(2)
    m, n, g = 16, 12, 4
    ig, og = _rand_grouping(key, m, n, g)
    w = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    x = jax.random.normal(jax.random.fold_in(key, 2), (5, m))
    cfg = flgw.FLGWConfig(groups=g, path="masked")

    def loss(ig, og):
        return jnp.sum(flgw.flgw_linear(x, w, ig, og, cfg) ** 2)

    dig, dog = jax.grad(loss, argnums=(0, 1))(ig, og)
    assert float(jnp.abs(dig).sum()) > 0
    assert float(jnp.abs(dog).sum()) > 0
    assert not bool(jnp.any(jnp.isnan(dig)) | jnp.any(jnp.isnan(dog)))


def test_transpose_uses_swapped_roles():
    """y = x @ (W⊙M)^T must equal the transpose trick's output."""
    key = jax.random.PRNGKey(3)
    m, n, g = 12, 20, 4
    ig, og = _rand_grouping(key, m, n, g)
    w = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
    x = jax.random.normal(jax.random.fold_in(key, 2), (4, n))
    cfg = flgw.FLGWConfig(groups=g, path="masked")
    y = flgw.flgw_linear(x, w, ig, og, cfg, transpose=True)
    ig_idx, og_idx = flgw.grouping_indices(ig, og)
    mask = flgw.mask_from_indices(ig_idx, og_idx)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x @ (w * mask).T),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# OSEL encoder
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 64), n=st.integers(2, 64),
       g=st.sampled_from([2, 4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_osel_encode_reconstructs_mask(m, n, g, seed):
    ig, og = _rand_grouping(jax.random.PRNGKey(seed), m, n, g)
    ig_idx, og_idx = flgw.grouping_indices(ig, og)
    mem = encode(ig_idx, og_idx, g)
    np.testing.assert_array_equal(
        np.asarray(mask_from_memory(mem)),
        np.asarray(flgw.mask_from_indices(ig_idx, og_idx)) > 0.5)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 48), n=st.integers(2, 48),
       g=st.sampled_from([2, 4, 8]), seed=st.integers(0, 2**31 - 1))
def test_osel_transpose_encode_is_mask_transpose(m, n, g, seed):
    """Backward-pass encoder: Mask^T via IG/OG role swap (paper §III-B)."""
    ig, og = _rand_grouping(jax.random.PRNGKey(seed), m, n, g)
    ig_idx, og_idx = flgw.grouping_indices(ig, og)
    mem_t = transpose_encode(ig_idx, og_idx, g)
    mask = np.asarray(flgw.mask_from_indices(ig_idx, og_idx)) > 0.5
    np.testing.assert_array_equal(np.asarray(mask_from_memory(mem_t)),
                                  mask.T)


def test_osel_workloads_match_row_nnz():
    key = jax.random.PRNGKey(7)
    ig, og = _rand_grouping(key, 32, 48, 8)
    ig_idx, og_idx = flgw.grouping_indices(ig, og)
    mem = encode(ig_idx, og_idx, 8)
    mask = np.asarray(flgw.mask_from_indices(ig_idx, og_idx))
    per_row = mask.sum(axis=1)
    np.testing.assert_array_equal(
        np.asarray(mem.workloads)[np.asarray(mem.index_list)], per_row)
