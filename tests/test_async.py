"""Async actor/learner pipeline: queue semantics, off-policy corrections,
staleness bounds, and the plan-consistent publication contract.

The anchors this file pins:

* queue depth 1 + ``correction="none"`` is BITWISE the synchronous
  ``lax.scan`` path (dense and grouped) — the decoupling itself changes
  nothing until staleness does;
* V-trace at staleness 0 reduces exactly to the on-policy update (the
  telescoping argument in ``async_train.vtrace``'s docstring, checked
  numerically and end-to-end);
* the learner never consumes a window older than ``max_staleness``
  publications;
* actors never step on a params/PlanState signature mismatch:
  :func:`~repro.marl.async_train.publish` certifies at the boundary,
  :func:`~repro.marl.async_train.adopt` heals a corrupted bundle, and the
  actor step itself traces zero ``make_plan`` calls.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import trace_counter
from repro.core import encoder, grouped
from repro.core.schedule import SparsitySchedule
from repro.launch import mesh as mesh_lib
from repro.marl import async_train as at
from repro.marl import envs as envs_mod
from repro.marl import ic3net
from repro.marl import train as train_mod

PP = envs_mod.get("predator_prey")


def _tiny_ecfg(**kw):
    base = dict(n_agents=2, size=3, vision=2, max_steps=6)
    base.update(kw)
    return PP.config_cls(**base)


def _assert_trees_equal(a, b, bitwise=True):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if bitwise:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=1e-5, rtol=1e-5)


# -- trajectory queue --------------------------------------------------------

def _item(i, shape=(2, 3)):
    return {"x": jnp.full(shape, i, jnp.float32),
            "n": jnp.full((), i, jnp.int32)}


def test_queue_fifo_and_wraparound():
    q = at.queue_init(3, jax.eval_shape(lambda: _item(0)))
    for i in range(5):                     # 5 pushes into capacity 3
        q = at.queue_push(q, _item(i), i)
    assert int(q.count) == 3 and int(q.pushed) == 5
    got = []
    for _ in range(3):
        item, ver, q = at.queue_pop(q)
        assert int(item["n"]) == int(ver)
        got.append(int(ver))
    assert got == [2, 3, 4]                # oldest two overwritten, FIFO out
    assert int(q.count) == 0


def test_queue_drop_policy_rejects_when_full():
    q = at.queue_init(2, jax.eval_shape(lambda: _item(0)))
    for i in range(4):
        q = at.queue_push(q, _item(i), i, policy="drop")
    assert int(q.count) == 2
    assert int(q.pushed) == 2 and int(q.dropped) == 2
    item, ver, q = at.queue_pop(q)
    assert int(ver) == 0                   # the first two survived
    item, ver, q = at.queue_pop(q)
    assert int(ver) == 1


def test_queue_pop_past_empty_clamps():
    q = at.queue_init(2, jax.eval_shape(lambda: _item(0)))
    q = at.queue_push(q, _item(7), 7)
    _, ver, q = at.queue_pop(q)
    assert int(ver) == 7 and int(q.count) == 0
    _, _, q = at.queue_pop(q)              # contract violation, but clamped
    assert int(q.count) == 0


def test_queue_sample_is_deterministic_and_uniform_over_valid():
    q = at.queue_init(4, jax.eval_shape(lambda: _item(0)))
    for i in range(6):                     # wraps: valid = {2, 3, 4, 5}
        q = at.queue_push(q, _item(i), i)
    key = jax.random.PRNGKey(0)
    a, va = at.queue_sample(q, key)
    b, vb = at.queue_sample(q, key)
    assert int(va) == int(vb)              # fixed key => same draw
    _assert_trees_equal(a, b)
    seen = {int(at.queue_sample(q, jax.random.PRNGKey(s))[1])
            for s in range(64)}
    assert seen <= {2, 3, 4, 5}            # never a dead slot
    assert len(seen) == 4                  # and every live one reachable


def test_queue_driver_mirrors_device_metadata():
    drv = at.QueueDriver(2, jax.eval_shape(lambda: _item(0)),
                         push_policy="overwrite")
    for i in range(3):
        drv.push(_item(i), i)
    assert len(drv) == 2 and int(drv.q.count) == 2
    assert drv.peek_version() == 1         # 0 was overwritten
    _, ver = drv.pop()
    assert ver == 1 and len(drv) == 1 == int(drv.q.count)


# -- maybe_refresh_plans is a pure delegate ----------------------------------

def test_maybe_refresh_plans_is_pure_delegate():
    """The sync scan, host loop and async learner drive ONE refresh
    implementation: ``train.maybe_refresh_plans`` must be bitwise
    ``encoder.maybe_refresh(params, plans, it, cfg.flgw, schedule)`` for
    every refresh mode — any divergence is a bug. Both sides run jitted
    (``it`` traced), the way every loop actually drives them."""
    import functools
    cfg, _, params, _ = train_mod._init(
        ic3net.IC3NetConfig(hidden=8, flgw_groups=4), _tiny_ecfg(), PP, 0)
    plans = encoder.encode_plans(params, cfg.flgw)
    moved = jax.tree.map(lambda x: x, params)
    for _, p in encoder.iter_flgw_layers(moved):
        p["ig"], p["og"] = -p["ig"], -p["og"]
    raw = functools.partial(jax.jit, static_argnames=("cfg", "schedule"))(
        encoder.maybe_refresh)
    schedules = [None] + [
        SparsitySchedule(groups=4, refresh_every=3, refresh=m)
        for m in encoder.REFRESH_MODES]
    for sched in schedules:
        for it in (0, 1, 3):
            for prm in (params, moved):
                got = train_mod._refresh_plans(prm, plans, it, cfg=cfg,
                                               schedule=sched)
                want = raw(prm, plans, it, cfg=cfg.flgw, schedule=sched)
                assert int(got.sig) == int(want.sig)
                _assert_trees_equal(got, want)


# -- correction = none: bitwise parity with the synchronous scan -------------

@pytest.mark.parametrize("groups", [1, 4])
def test_depth1_no_correction_bitwise_matches_sync_scan(groups):
    """The decoupling acceptance bar: queue depth 1, one actor window per
    update, correction off => the async pipeline IS the synchronous scan,
    bitwise, on both the dense and the grouped (plan-consuming) path."""
    cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=groups)
    ecfg = _tiny_ecfg()
    tcfg = train_mod.TrainConfig(batch=4)
    acfg = at.AsyncConfig(capacity=1, actors=1, correction="none",
                          publish_every=1)
    p_async, h_async = at.async_train(cfg, ecfg, tcfg, acfg=acfg,
                                      updates=3, seed=0,
                                      check_publication=True)
    p_sync, h_sync = train_mod.train(cfg, ecfg, tcfg, iterations=3, seed=0)
    _assert_trees_equal(p_async, p_sync)
    np.testing.assert_array_equal([h["loss"] for h in h_async],
                                  [h["loss"] for h in h_sync])
    np.testing.assert_array_equal([h["success"] for h in h_async],
                                  [h["success"] for h in h_sync])
    assert all(h["staleness"] == 0 for h in h_async)


def test_replay_terms_reproduce_rollout_terms_at_equal_params():
    """The learner's re-unroll over a stored window is the same graph the
    rollout ran: at equal params the replayed (logp, val, ent, gate_logp)
    must be bitwise the actor's."""
    cfg = ic3net.IC3NetConfig(hidden=16)
    ecfg = _tiny_ecfg()
    tcfg = train_mod.TrainConfig(batch=4)
    cfg, key, params, _ = train_mod._init(cfg, ecfg, PP, 0)
    key, k = jax.random.split(key)
    keys = jax.random.split(k, tcfg.batch)
    rew, logp, val, ent, gate_logp, gates, obs, act, succ = jax.vmap(
        lambda kk: train_mod.rollout(params, kk, cfg, ecfg, PP,
                                     collect=True))(keys)
    traj = at.Trajectory(obs=obs, act=act, gates=gates, rew=rew,
                         logp=logp, succ=succ)
    r_logp, r_val, r_ent, r_glogp = at.replay_terms(params, cfg, traj)
    for got, want in ((r_logp, logp), (r_val, val), (r_ent, ent),
                      (r_glogp, gate_logp)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -- V-trace -----------------------------------------------------------------

def test_vtrace_on_policy_reduces_to_mc_returns():
    """rho = c = 1 (equal behavior/target policies) telescopes the V-trace
    recursion into plain discounted returns-to-go: vs = returns and
    pg_adv = returns - val — exactly the synchronous A2C quantities."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    rew = jax.random.normal(k1, (3, 7, 2))
    val = jax.random.normal(k2, (3, 7, 2))
    logp = jax.random.normal(k3, (3, 7, 2))
    gamma = 0.9
    vs, pg_adv, rho = at.vtrace(logp, logp, rew, val, gamma=gamma)
    np.testing.assert_array_equal(np.asarray(rho), 1.0)
    returns = np.zeros_like(np.asarray(rew))
    acc = np.zeros((3, 2))
    for t in range(6, -1, -1):
        acc = np.asarray(rew)[:, t] + gamma * acc
        returns[:, t] = acc
    np.testing.assert_allclose(np.asarray(vs), returns, atol=1e-5)
    np.testing.assert_allclose(np.asarray(pg_adv),
                               returns - np.asarray(val), atol=1e-5)


def test_vtrace_pipeline_at_staleness0_matches_sync_update():
    """End-to-end: correction="vtrace" with depth 1 / publish-every-update
    (staleness 0 throughout) must land on the synchronous params —
    allclose, not bitwise: the V-trace vloss target algebraically equals
    (returns - val) but associates its FP reductions differently."""
    cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=4)
    ecfg = _tiny_ecfg()
    tcfg = train_mod.TrainConfig(batch=4)
    acfg = at.AsyncConfig(capacity=1, actors=1, correction="vtrace")
    p_async, h_async = at.async_train(cfg, ecfg, tcfg, acfg=acfg,
                                      updates=2, seed=0)
    p_sync, _ = train_mod.train(cfg, ecfg, tcfg, iterations=2, seed=0)
    assert all(h["staleness"] == 0 for h in h_async)
    assert all(h["mean_is"] == 1.0 for h in h_async)
    _assert_trees_equal(p_async, p_sync, bitwise=False)


def test_clip_correction_on_policy_matches_sync_update():
    cfg = ic3net.IC3NetConfig(hidden=16)
    ecfg = _tiny_ecfg()
    tcfg = train_mod.TrainConfig(batch=4)
    acfg = at.AsyncConfig(capacity=1, actors=1, correction="clip")
    p_async, h_async = at.async_train(cfg, ecfg, tcfg, acfg=acfg,
                                      updates=2, seed=0)
    p_sync, _ = train_mod.train(cfg, ecfg, tcfg, iterations=2, seed=0)
    assert all(h["mean_is"] == 1.0 for h in h_async)
    _assert_trees_equal(p_async, p_sync, bitwise=False)


def test_vtrace_training_reaches_sync_reward_under_staleness():
    """The acceptance run: with real staleness (publish every 2 updates,
    queue depth 2) V-trace training on predator_prey lands in the same
    success band as the synchronous fig9-style run at equal budget."""
    cfg = ic3net.IC3NetConfig(hidden=32)
    ecfg = _tiny_ecfg()
    tcfg = train_mod.TrainConfig(batch=16)
    acfg = at.AsyncConfig(capacity=2, actors=1, correction="vtrace",
                          publish_every=2, max_staleness=4)
    p_s, h_s = train_mod.train(cfg, ecfg, tcfg, iterations=40, seed=1)
    p_a, h_a = at.async_train(cfg, ecfg, tcfg, acfg=acfg, updates=40,
                              seed=1)
    assert max(h["staleness"] for h in h_a) >= 1   # genuinely off-policy
    sync_last = np.mean([h["success"] for h in h_s[-10:]])
    async_last = np.mean([h["success"] for h in h_a[-10:]])
    assert async_last >= sync_last - 0.1
    # and it learned at all (the tiny-task sanity bar the sync test uses)
    async_first = np.mean([h["success"] for h in h_a[:5]])
    assert async_last >= async_first - 0.05


# -- staleness bound ---------------------------------------------------------

def test_learner_never_consumes_over_the_staleness_bound():
    """Windows older than max_staleness publications are evicted, never
    trained on — even when the actor cadence floods the queue."""
    cfg = ic3net.IC3NetConfig(hidden=16)
    ecfg = _tiny_ecfg()
    tcfg = train_mod.TrainConfig(batch=2)
    acfg = at.AsyncConfig(capacity=8, actors=3, correction="vtrace",
                          max_staleness=1, publish_every=2)
    _, hist = at.async_train(cfg, ecfg, tcfg, acfg=acfg, updates=8, seed=0)
    assert len(hist) == 8
    assert max(h["staleness"] for h in hist) <= 1


def test_max_staleness_zero_forces_on_policy():
    cfg = ic3net.IC3NetConfig(hidden=16)
    ecfg = _tiny_ecfg()
    tcfg = train_mod.TrainConfig(batch=2)
    acfg = at.AsyncConfig(capacity=4, actors=2, correction="vtrace",
                          max_staleness=0, publish_every=3)
    _, hist = at.async_train(cfg, ecfg, tcfg, acfg=acfg, updates=6, seed=0)
    assert all(h["staleness"] == 0 for h in hist)


# -- plan-consistent publication ---------------------------------------------

def _grouped_setup():
    cfg, key, params, _ = train_mod._init(
        ic3net.IC3NetConfig(hidden=16, flgw_groups=4), _tiny_ecfg(), PP, 0)
    plans = encoder.encode_plans(params, cfg.flgw)
    return cfg, key, params, plans


def _move_layouts(params):
    moved = jax.tree.map(lambda x: x, params)
    for _, p in encoder.iter_flgw_layers(moved):
        p["ig"], p["og"] = -p["ig"], -p["og"]
    return moved


def test_publish_certifies_plans_against_params():
    """Publication is the boundary staleness must not cross: publishing
    NEW params with the OLD PlanState must hand actors a bundle whose
    plans are bitwise a fresh encode of the new params."""
    cfg, _, params, plans = _grouped_setup()
    moved = _move_layouts(params)
    bundle = at.publish(moved, plans, 1, cfg)
    assert bool(at.bundle_consistent(bundle))
    fresh = encoder.encode_plans(moved, cfg.flgw)
    assert int(bundle.plans.sig) == int(fresh.sig)
    _assert_trees_equal(bundle.plans, fresh)
    assert int(bundle.version) == 1


def test_adopt_heals_a_mismatched_bundle():
    """The actor-side swap gate: a corrupted bundle (params/plans from
    different versions) is detected by bundle_consistent and healed by
    adopt — actors can never run grouped kernels on foreign metadata."""
    cfg, _, params, plans = _grouped_setup()
    moved = _move_layouts(params)
    bad = at.ParamBundle(moved, plans, jnp.asarray(1, jnp.int32))
    assert not bool(at.bundle_consistent(bad))
    healed = at.adopt(bad, cfg)
    assert bool(at.bundle_consistent(healed))
    _assert_trees_equal(healed.plans, encoder.encode_plans(moved, cfg.flgw))
    # a consistent bundle passes through bitwise (certify, no re-encode)
    good = at.publish(params, plans, 0, cfg)
    same = at.adopt(good, cfg)
    _assert_trees_equal(same.plans, good.plans)


def test_actor_step_traces_zero_plan_encodes():
    """Actors only CONSUME published plans: tracing the actor rollout with
    a certified bundle must hit make_plan zero times — all encode work
    lives behind the publication boundary."""
    cfg, key, params, plans = _grouped_setup()
    bundle = at.publish(params, plans, 0, cfg)
    ecfg = _tiny_ecfg()
    tcfg = train_mod.TrainConfig(batch=2)
    with trace_counter(grouped, "make_plan") as calls:
        jax.eval_shape(
            lambda p, k, pl: at.actor_rollout(p, k, cfg, ecfg, tcfg,
                                              PP, pl),
            bundle.params, key, bundle.plans)
    assert calls.count == 0


def test_async_train_check_publication_holds_across_versions():
    """The end-to-end version guard: every published bundle over a short
    grouped run certifies (the in-driver assertions fire otherwise)."""
    cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=4)
    _, hist = at.async_train(cfg, _tiny_ecfg(),
                             train_mod.TrainConfig(batch=2),
                             acfg=at.AsyncConfig(capacity=2, actors=1,
                                                 publish_every=2),
                             updates=4, seed=0, check_publication=True)
    assert len(hist) == 4


def test_async_rejects_dense_warmup_schedule():
    sched = SparsitySchedule(groups=4, refresh_every=1, warmup_steps=5)
    with pytest.raises(NotImplementedError, match="warm up"):
        at.async_train(ic3net.IC3NetConfig(hidden=16, flgw_groups=4),
                       _tiny_ecfg(), train_mod.TrainConfig(batch=2),
                       schedule=sched, updates=1)


# -- threaded overlap and distributed helpers --------------------------------

def test_threaded_pipeline_runs_and_respects_bounds():
    cfg = ic3net.IC3NetConfig(hidden=16)
    ecfg = _tiny_ecfg()
    tcfg = train_mod.TrainConfig(batch=2)
    acfg = at.AsyncConfig(capacity=4, actors=1, correction="vtrace",
                          max_staleness=2, publish_every=1)
    _, hist = at.async_train(cfg, ecfg, tcfg, acfg=acfg, updates=5, seed=0,
                             threads=True)
    assert len(hist) == 5
    assert max(h["staleness"] for h in hist) <= 2
    assert threading.active_count() >= 1   # actor thread joined cleanly


def test_init_distributed_falls_back_to_single_process(monkeypatch):
    for var in ("JAX_COORDINATOR", "COORDINATOR_ADDRESS",
                "JAX_NUM_PROCESSES", "JAX_PROCESS_ID"):
        monkeypatch.delenv(var, raising=False)
    info = mesh_lib.init_distributed()
    assert info["distributed"] is False
    assert info["process_count"] == 1 and info["process_index"] == 0
    assert info["global_devices"] >= info["local_devices"] >= 1


def test_host_local_batch_slices_evenly(monkeypatch):
    local, offset = mesh_lib.host_local_batch(16)
    assert (local, offset) == (16, 0)      # single process owns everything
    # a simulated 4-host topology: process 2 owns rows [8, 12)
    monkeypatch.setattr(mesh_lib.jax, "process_count", lambda: 4)
    monkeypatch.setattr(mesh_lib.jax, "process_index", lambda: 2)
    assert mesh_lib.host_local_batch(16) == (4, 8)
    with pytest.raises(ValueError, match="does not divide"):
        mesh_lib.host_local_batch(17)


def test_async_config_validates():
    with pytest.raises(ValueError, match="correction"):
        at.AsyncConfig(correction="nope")
    with pytest.raises(ValueError, match="push_policy"):
        at.AsyncConfig(push_policy="nope")
    with pytest.raises(ValueError, match=">= 1"):
        at.AsyncConfig(capacity=0)
