"""Compact (grouped) execution path: plan invariants + custom VJP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import flgw
from repro.core.grouped import balanced_assign, grouped_apply, make_plan


@settings(max_examples=25, deadline=None)
@given(m=st.integers(4, 96), g=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31 - 1))
def test_balanced_assign_partitions_all_items(m, g, seed):
    """Every row appears exactly once across the G equal-capacity buckets."""
    scores = jax.random.normal(jax.random.PRNGKey(seed), (m, g))
    ids = np.asarray(balanced_assign(scores, axis=1))
    cap = -(-m // g)
    assert ids.shape == (g, cap)
    valid = ids[ids < m]
    assert sorted(valid.tolist()) == list(range(m))


@settings(max_examples=15, deadline=None)
@given(m=st.integers(8, 64), n=st.integers(8, 64),
       g=st.sampled_from([2, 4]), seed=st.integers(0, 2**31 - 1))
def test_plan_group_sizes_are_exactly_balanced(m, n, g, seed):
    """The TPU adaptation: every group holds exactly cap slots — the
    static-shape analogue of the paper's row-based balancing."""
    key = jax.random.PRNGKey(seed)
    ig = jax.random.normal(key, (m, g))
    og = jax.random.normal(jax.random.fold_in(key, 1), (g, n))
    plan = make_plan(ig, og)
    rv = np.asarray(plan.row_valid).sum(axis=1)
    cv = np.asarray(plan.col_valid).sum(axis=1)
    assert rv.sum() == m and cv.sum() == n
    assert rv.max() - rv.min() <= 1 + (g * (-(-m // g)) - m)
    assert cv.max() - cv.min() <= 1 + (g * (-(-n // g)) - n)


def test_grouped_apply_gradients_match_masked_path_when_aligned():
    """With permutation-structured grouping (no spill), the compact path's
    dX/dW must equal the masked oracle's gradients."""
    m = n = 32
    g = 4
    key = jax.random.PRNGKey(0)
    row_groups = jnp.tile(jnp.arange(g), m // g)
    col_groups = jnp.tile(jnp.arange(g), n // g)
    ig = jax.nn.one_hot(row_groups, g) * 8.0
    og = jax.nn.one_hot(col_groups, g, axis=0).reshape(g, n) * 8.0
    w = jax.random.normal(key, (m, n))
    x = jax.random.normal(jax.random.fold_in(key, 1), (6, m))
    gy = jax.random.normal(jax.random.fold_in(key, 2), (6, n))
    cfg = flgw.FLGWConfig(groups=g, path="grouped")

    def f_grouped(x, w):
        return jnp.sum(grouped_apply(x, w, ig, og, cfg) * gy)

    def f_masked(x, w):
        mask = flgw.mask_from_indices(row_groups.astype(jnp.int32),
                                      col_groups.astype(jnp.int32))
        return jnp.sum((x @ (w * mask)) * gy)

    gx1, gw1 = jax.grad(f_grouped, argnums=(0, 1))(x, w)
    gx2, gw2 = jax.grad(f_masked, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2),
                               rtol=1e-4, atol=1e-4)


def test_grouped_apply_grouping_matrices_get_gradients():
    key = jax.random.PRNGKey(3)
    m, n, g = 24, 16, 4
    ig = jax.random.normal(key, (m, g))
    og = jax.random.normal(jax.random.fold_in(key, 1), (g, n))
    w = jax.random.normal(jax.random.fold_in(key, 2), (m, n))
    x = jax.random.normal(jax.random.fold_in(key, 3), (4, m))
    cfg = flgw.FLGWConfig(groups=g, path="grouped")

    def loss(ig, og):
        return jnp.sum(grouped_apply(x, w, ig, og, cfg) ** 2)

    dig, dog = jax.grad(loss, argnums=(0, 1))(ig, og)
    assert np.isfinite(np.asarray(dig)).all()
    assert np.isfinite(np.asarray(dog)).all()
    assert float(jnp.abs(dig).sum()) > 0
    assert float(jnp.abs(dog).sum()) > 0


def test_grouped_apply_transpose_matches_forward_transpose():
    """The weight-transpose trick on the compact path (backward reuse)."""
    m, n, g = 32, 32, 4
    key = jax.random.PRNGKey(4)
    row_groups = jnp.tile(jnp.arange(g), m // g)
    col_groups = jnp.tile(jnp.arange(g), n // g)
    ig = jax.nn.one_hot(row_groups, g) * 8.0
    og = jax.nn.one_hot(col_groups, g, axis=0).reshape(g, n) * 8.0
    w = jax.random.normal(key, (m, n))
    x = jax.random.normal(jax.random.fold_in(key, 1), (5, n))
    cfg = flgw.FLGWConfig(groups=g, path="grouped")
    y = grouped_apply(x, w, ig, og, cfg, transpose=True)
    mask = flgw.mask_from_indices(row_groups.astype(jnp.int32),
                                  col_groups.astype(jnp.int32))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ (w * mask).T),
                               rtol=1e-4, atol=1e-4)


def test_grouped_flops_reduction_matches_g():
    """The compact tiles hold m·n/g weight slots (÷G compute/bytes)."""
    m = n = 64
    for g in (2, 4, 8):
        key = jax.random.PRNGKey(g)
        ig = jax.random.normal(key, (m, g))
        og = jax.random.normal(jax.random.fold_in(key, 1), (g, n))
        plan = make_plan(ig, og)
        compact = plan.row_ids.shape[1] * plan.col_ids.shape[1] * g
        assert compact == m * n // g
