"""ServeSession surface: plan policies, the process-wide plan cache, and
the deprecated ``repro.train.step`` shims.

The API-consolidation contract this file pins:

* ``plan_policy`` is the one knob — ``certify`` re-resolves plans at
  request boundaries (and picks up online-tuning updates), ``trust``
  consumes the resolved PlanState unconditionally, ``off`` serves
  planless (per-call re-encode in the projections).
* N sessions / requests against one params version cost ONE
  ``make_plan``-per-layer encode, process-wide (the plan cache).
* the PR-6 ``repro.train.step`` deprecation shims (``make_serve_step`` /
  ``make_prefill_step``) are retired — the names must NOT resolve there
  anymore; ``repro.serving`` is the only surface.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import trace_counter
from repro.configs import registry
from repro.core import encoder, grouped
from repro.models import transformer
from repro.serving import (PLAN_POLICIES, ServeSession, make_decode_step,
                           make_prefill_step, plan_cache)
from repro.train import step as step_lib


def _cfg(**kw):
    base = dict(flgw_groups=4, flgw_path="grouped", flgw_targets=("mlp",))
    base.update(kw)
    return registry.get_smoke_config("gemma2_2b", **base)


def _flip_grouping(params):
    """Online-tuning stand-in: negating ig/og moves every layout."""
    flipped = jax.tree.map(lambda x: x, params)
    for _, p in encoder.iter_flgw_layers(flipped):
        p["ig"] = -p["ig"]
        p["og"] = -p["og"]
    return flipped


@pytest.fixture(autouse=True)
def _fresh_plan_cache():
    plan_cache.clear()
    yield
    plan_cache.clear()


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    params, _ = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


# -- policy semantics --------------------------------------------------------

def test_policy_validation():
    cfg = _cfg()
    params, _ = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="plan_policy"):
        ServeSession(cfg, params, plan_policy="always")
    assert set(PLAN_POLICIES) == {"certify", "trust", "off"}


def test_certify_tracks_online_tuning(served):
    """certify: after params move, a refresh hands back exactly what a
    fresh encode of the new params would produce."""
    cfg, params = served
    sess = ServeSession(cfg, params, plan_policy="certify")
    cache = sess.new_cache(1, 8)
    assert isinstance(cache["plans"], encoder.PlanState)
    old_sig = int(cache["plans"].sig)

    sess.update_params(_flip_grouping(params))
    cache = sess.refresh(cache)
    # session caches carry the compact weights (the fused-path operand):
    # the expectation is the fresh encode with wc attached from new params
    fresh = encoder.attach_compact(
        transformer.encode_plans(sess.params, cfg), sess.params)
    assert int(cache["plans"].sig) == int(fresh.sig) != old_sig
    for a, b in zip(jax.tree.leaves(cache["plans"]), jax.tree.leaves(fresh)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trust_skips_boundary_work(served):
    """trust: refresh is a no-op even when params moved underneath —
    that is the policy's stated contract (caller owns update_params)."""
    cfg, params = served
    sess = ServeSession(cfg, params, plan_policy="trust")
    cache = sess.new_cache(1, 8)
    stale_sig = int(cache["plans"].sig)
    sess.params = _flip_grouping(params)      # move WITHOUT update_params
    cache = sess.refresh(cache)
    assert int(cache["plans"].sig) == stale_sig


def test_off_serves_planless(served):
    cfg, params = served
    sess = ServeSession(cfg, params, plan_policy="off")
    assert sess.plans == ()
    cache = sess.new_cache(1, 8)
    assert cache["plans"] == ()


def test_policies_decode_identically(served):
    """The policies are about *when* metadata is produced, never about
    the math: one decode step agrees bitwise across all three."""
    cfg, params = served
    tok = jnp.zeros((1, 1), jnp.int32)
    pos = jnp.zeros((1, 1), jnp.int32)
    outs = {}
    for policy in PLAN_POLICIES:
        sess = ServeSession(cfg, params, plan_policy=policy)
        nxt, _ = sess.decode(sess.new_cache(1, 8), tok, pos)
        outs[policy] = np.asarray(nxt)  # noqa: ANL002 — one decode per policy, fetched for comparison
    np.testing.assert_array_equal(outs["certify"], outs["trust"])
    np.testing.assert_array_equal(outs["certify"], outs["off"])


# -- the process-wide plan cache ---------------------------------------------

def test_shared_plans_one_encode_for_n_sessions(served):
    """Trace-count guard: N concurrent sessions over one params version
    cost exactly one ``make_plan`` per FLGW layer, process-wide."""
    cfg, params = served
    n_layers = sum(1 for _ in encoder.iter_flgw_layers(params))
    assert n_layers > 0
    with trace_counter(grouped, "make_plan") as calls:
        sessions = [ServeSession(cfg, params, plan_policy="certify")
                    for _ in range(4)]
        assert calls.count == n_layers            # ONE encode total
    first = sessions[0].plans
    for s in sessions[1:]:
        assert s.plans is first                   # literally shared
    st = plan_cache.stats()
    assert st["encodes"] == 1 and st["hits"] == 3


def test_fused_decode_no_per_call_make_plan(served):
    """Trace-count guard for the fused consume path: a cache built by the
    session carries compact weights (``GroupPlan.wc`` — the fused
    ``flgw_matmul`` prologue's operand), and decoding with it costs ZERO
    ``make_plan`` calls and zero re-gathers of ``wc`` — the OSEL handoff
    stays encode-once/consume-many, same as the XLA-gather path before."""
    cfg, params = served
    sess = ServeSession(cfg, params, plan_policy="trust")
    cache = sess.new_cache(1, 8)
    assert grouped.has_compact(cache["plans"].plans)
    attached = cache["plans"]

    with trace_counter(grouped, "make_plan") as plan_calls, \
            trace_counter(grouped, "attach_compact") as attach_calls:
        tok = jnp.zeros((1, 1), jnp.int32)
        for i in range(3):
            tok, cache = sess.decode(cache, tok,
                                     sess.greedy_positions(1, i))
        assert plan_calls.count == 0
        assert attach_calls.count == 0
        # a second cache against the same (plans, params) pair reuses the
        # session-local memo — still no re-gather
        cache2 = sess.new_cache(1, 8)
        assert cache2["plans"] is attached
        assert attach_calls.count == 0


def test_shared_cache_state_stays_weight_free(served):
    """The process-wide plan cache is keyed by the layout signature, which
    never hashes weight values — the states it holds (and ``session.plans``,
    shared by identity) must therefore stay wc-free; weights attach only
    session-locally at consumption points."""
    cfg, params = served
    sess = ServeSession(cfg, params)
    assert not grouped.has_compact(sess.plans.plans)
    cache = sess.new_cache(1, 8)
    assert grouped.has_compact(cache["plans"].plans)
    assert not grouped.has_compact(sess.plans.plans)  # untouched
    # refresh certifies and re-attaches without polluting the shared state
    cache = sess.refresh(cache)
    assert grouped.has_compact(cache["plans"].plans)
    assert not grouped.has_compact(sess.plans.plans)


def test_new_params_version_encodes_once_more(served):
    cfg, params = served
    sess = ServeSession(cfg, params)
    sess.update_params(_flip_grouping(params))
    sess.update_params(params)                    # back to a cached version
    st = plan_cache.stats()
    assert st["encodes"] == 2                     # v1 + flipped, no third
    assert st["entries"] == 2


def test_share_plans_off_bypasses_cache(served):
    cfg, params = served
    ServeSession(cfg, params, share_plans=False)
    st = plan_cache.stats()
    assert st["hits"] == st["misses"] == st["encodes"] == 0


def test_plan_cache_lru_bound(served):
    cfg, params = served
    sess = ServeSession(cfg, params)

    def version(i):
        p = jax.tree.map(lambda x: x, params)
        for j, (_, lay) in enumerate(encoder.iter_flgw_layers(p)):
            k = jax.random.PRNGKey(1000 * i + j)
            lay["ig"] = jax.random.normal(k, lay["ig"].shape)
            lay["og"] = jax.random.normal(jax.random.fold_in(k, 1),
                                          lay["og"].shape)
        return p

    for i in range(plan_cache.MAX_ENTRIES + 2):
        sess.update_params(version(i))
    assert plan_cache.stats()["entries"] == plan_cache.MAX_ENTRIES


# -- retired shims -----------------------------------------------------------

def test_train_step_shims_are_retired():
    """The PR-6 deprecation bridge is gone: serving factories must not
    resolve from ``repro.train`` anymore (``repro.serving`` is the one
    surface), and the train package must not re-export them."""
    import repro.train as train_pkg
    assert not hasattr(step_lib, "make_serve_step")
    assert not hasattr(step_lib, "make_prefill_step")
    assert not hasattr(train_pkg, "make_serve_step")
    assert not hasattr(train_pkg, "make_prefill_step")
    assert "make_serve_step" not in train_pkg.__all__


def test_new_factories_do_not_warn(served):
    cfg, _ = served
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        make_decode_step(cfg)
        make_prefill_step(cfg)
    assert not any(issubclass(c.category, DeprecationWarning)
                   for c in caught), caught
