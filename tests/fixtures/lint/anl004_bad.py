"""Positive fixture: undeclared custom_vjp statics (ANL004)."""
import functools

import jax
import jax.numpy as jnp


@jax.custom_vjp
def relu_undeclared(x, approximate: bool = True):
    # ANL004: bool param not in nondiff_argnums; no defvjp registration
    return jnp.maximum(x, 0.0) if approximate else x


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def scale_out_of_range(x, s):
    # ANL004: nondiff index 5 out of range for 2 positional params
    return x * s


def _scale_fwd(x, s):
    return x * s, (x, s)


def _scale_bwd(res, g):
    x, s = res
    return g * s, g * x


scale_out_of_range.defvjp(_scale_fwd, _scale_bwd)


@jax.custom_vjp
def kw_only_mode(x, *, mode: str = "fast"):
    # ANL004: keyword-only params are unsupported by custom_vjp
    return x


def _kw_fwd(x):
    return x, None


def _kw_bwd(_, g):
    return (g,)


kw_only_mode.defvjp(_kw_fwd, _kw_bwd)
