"""Negative fixture: a pallas_call module that registers its own
KernelSpec in the same file lints clean under ANL006."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.analysis.kernel_audit import (GridCase, KernelSpec, Operand,
                                         register_kernel_spec)

BM = 8
BN = 16


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def audited(x):
    return pl.pallas_call(
        _kernel,
        grid=(2, 2),
        in_specs=[pl.BlockSpec((BM, BN), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((BM * 2, BN * 2), jnp.float32),
    )(x)


def _case(p):
    return GridCase(
        label="fixture", grid=(2, 2),
        operands=(
            Operand("x", (BM * 2, BN * 2), (BM, BN),
                    lambda i, j: (i, j)),
            Operand("o", (BM * 2, BN * 2), (BM, BN),
                    lambda i, j: (i, j), role="out"),
        ),
    )


register_kernel_spec(KernelSpec(
    name="fixture.audited", module=__name__, build=_case, corpus=({},)))
