"""Fixture corpus for ``repro.analysis.lint`` (tests/test_analysis.py).

Each rule has a positive fixture (``anl00x_bad.py`` — deliberately
violates the rule) and a negative one (``anl00x_good.py`` — exercises
the same constructs correctly and must lint clean). This ``__init__.py``
exists so the ANL001 importability heuristic (sibling ``__init__.py``)
fires on the fixtures; the files are never imported at runtime, only
parsed. The directory is in the linter's DEFAULT_EXCLUDES so the
repo-wide CI run never trips over the positive corpus.
"""
