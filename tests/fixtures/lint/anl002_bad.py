"""Positive fixture: host-device syncs in traced contexts (ANL002)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def jitted_loss(x):
    return float(jnp.sum(x))             # ANL002: float() under jit


@functools.partial(jax.jit, static_argnames=("n",))
def jitted_cumsum(x, n):
    return np.asarray(jnp.cumsum(x))[:n]   # ANL002: np.asarray under jit


def make_train_step(cfg):
    def train_step(state, batch):
        loss = jnp.mean(batch)
        return state, loss.item()        # ANL002: .item() in a factory step
    return train_step


def _scan_body(carry, x):
    s = carry + x
    return s, float(jnp.sum(s))          # ANL002: float() in a scan body


def run_scan(xs):
    return jax.lax.scan(_scan_body, jnp.zeros(()), xs)


def drive(session, cache, tok, pos, steps):
    outs = []
    for _ in range(steps):
        tok, cache = session.decode(cache, tok, pos)
        outs.append(np.asarray(tok))     # ANL002: per-step fetch, hot loop
    return outs
