"""Positive fixture: scan carry structure mismatches (ANL005)."""
import jax
import jax.numpy as jnp


def _drops_state(carry, x):
    h, c = carry
    h = h + x + c
    return (h,), h         # ANL005: unpacks 2-element carry, returns 1


def run_drop(xs):
    init = (jnp.zeros(()), jnp.zeros(()))
    return jax.lax.scan(_drops_state, init, xs)


def _triple(carry, x):
    s = carry + x
    return s, s, s         # ANL005: 3-tuple, not a (carry, ys) pair


def run_triple(xs):
    return jax.lax.scan(_triple, jnp.zeros(()), xs)


def run_lambda(xs):
    # ANL005: init literal has 2 elements, carry-out has 3
    return jax.lax.scan(lambda c, x: ((c[0], c[1], x), x),
                        (jnp.zeros(()), jnp.ones(())), xs)
