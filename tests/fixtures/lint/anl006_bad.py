"""Positive fixture: pallas_call sites with no KernelSpec registered
(ANL006). Both calls are structurally consistent so only ANL006 fires;
there is no register_kernel_spec here and no sibling audit.py naming
this module."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 8
BN = 16


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def unaudited_one(x):
    # ANL006: no KernelSpec registration anywhere for this module
    return pl.pallas_call(
        _kernel,
        grid=(2, 2),
        in_specs=[pl.BlockSpec((BM, BN), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((BM * 2, BN * 2), jnp.float32),
    )(x)


def unaudited_two(x):
    # ANL006: second unregistered site — one finding per call
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((BM, BN), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BM, BN), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((BM, BN), jnp.float32),
    )(x)
