"""Positive fixture: import-time device-array construction (ANL001).

The PR-8 lockout regression class: a module-level jnp constant commits
the process to a backend at import, so the ``jax.distributed.initialize``
call in ``main`` dies with "backend already initialized" on multi-host
bring-up — exactly what happened when the MARL env modules grew
module-level constants.
"""
import jax
import jax.numpy as jnp

_OFFSETS = jnp.arange(4)            # ANL001: array at import time
_KEY = jax.random.PRNGKey(0)        # ANL001: jax.random at import time
_N = jax.device_count()             # ANL001: backend query at import time

try:
    _FALLBACK = jnp.zeros((2,))     # ANL001: try-body still runs at import
except RuntimeError:
    _FALLBACK = None


def main():
    # too late: the constants above already initialized a backend
    jax.distributed.initialize()
    return _OFFSETS
