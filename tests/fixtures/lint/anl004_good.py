"""Negative fixture: a fully declared custom_vjp lints clean (ANL004)."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def leaky(x, slope: str = "soft"):
    scale = 0.01 if slope == "soft" else 0.1
    return jnp.where(x > 0, x, scale * x)


def _leaky_fwd(x, slope):
    return leaky(x, slope), x


def _leaky_bwd(slope, x, g):
    scale = 0.01 if slope == "soft" else 0.1
    return (jnp.where(x > 0, g, scale * g),)


leaky.defvjp(_leaky_fwd, _leaky_bwd)
