"""Negative fixture: lazy / numpy module constants lint clean (ANL001)."""
import jax
import jax.numpy as jnp
import numpy as np

_TABLE = np.arange(4)       # numpy at import is host-only, fine
_DTYPE = jnp.float32        # a dtype reference, not a constructor


def offsets():
    return jnp.asarray(_TABLE)    # device materialization deferred to call


def main():
    jax.distributed.initialize()  # runs before any device array exists
    return offsets()
