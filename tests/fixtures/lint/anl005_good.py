"""Negative fixture: a structurally matched scan carry lints clean
(ANL005)."""
import jax
import jax.numpy as jnp


def _lstm_step(carry, x):
    h, c = carry
    h2 = jnp.tanh(x + h)
    c2 = c + h2
    return (h2, c2), h2


def run(xs):
    init = (jnp.zeros(()), jnp.zeros(()))
    return jax.lax.scan(_lstm_step, init, xs)


def run_lambda(xs):
    return jax.lax.scan(lambda c, x: ((c[0] + x, c[1]), c[0]),
                        (jnp.zeros(()), jnp.ones(())), xs)
