"""Negative fixture: a structurally consistent pallas_call lints clean
(ANL003), including the closure-capture index_map default idiom."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM = 8
BN = 16
INTERPRET = True


def _kernel(x_ref, o_ref, acc_ref, flag_ref):
    acc_ref[...] = x_ref[...] * 2.0
    o_ref[...] = acc_ref[...]


def consistent(x, qpk=2):
    return pl.pallas_call(  # noqa: ANL006
        _kernel,
        grid=(2, 2),
        in_specs=[pl.BlockSpec((BM, BN),
                               lambda i, j, qpk=qpk: (i * qpk, j))],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((BM * 2, BN * 2), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32),
                        pltpu.SMEM((1, 1), jnp.float32)],
        interpret=INTERPRET,
    )(x)
