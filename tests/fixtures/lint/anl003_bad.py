"""Positive fixture: pallas_call structural inconsistencies (ANL003)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM = 8
BN = 16


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] * 2.0


def arity_mismatch(x):
    # ANL003: in_specs index_map takes 1 grid index, grid has 2 dims
    return pl.pallas_call(  # noqa: ANL006
        _kernel,
        grid=(2, 2),
        in_specs=[pl.BlockSpec((BM, BN), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((BM * 2, BN * 2), jnp.float32),
    )(x)


def rank_mismatch(x):
    # ANL003: out_specs block shape is rank 2, out_shape is rank 1
    return pl.pallas_call(  # noqa: ANL006
        _kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec((BM, BN), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BM, BN), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((BM * 2,), jnp.float32),
    )(x)


def operand_mismatch(x, y):
    # ANL003: 1 in_spec but the call is applied to 2 operands
    return pl.pallas_call(  # noqa: ANL006
        _kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((BM, BN), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BM, BN), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((BM, BN), jnp.float32),
    )(x, y)


def scratch_mismatch(x):
    # ANL003: scratch dim 32 is not drawn from any block shape
    return pl.pallas_call(  # noqa: ANL006
        _kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((BM, BN), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BM, BN), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((BM, BN), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BM, 32), jnp.float32)],
    )(x)


def traced_interpret(x, flag):
    # ANL003: interpret= is a computed value, not a Python bool
    return pl.pallas_call(  # noqa: ANL006
        _kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((BM, BN), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BM, BN), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((BM, BN), jnp.float32),
        interpret=bool(jnp.asarray(flag)),
    )(x)
