"""Negative fixture: static casts and windowed fetches lint clean
(ANL002)."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def scaled(x):
    scale = float(x.shape[-1] ** -0.5)   # static shape arithmetic
    return x * scale


def make_eval_step(cfg):
    def eval_step(params, batch):
        return jnp.mean(batch) * float(len(cfg))   # len() is host data
    return eval_step


def drive(session, cache, tok, pos, steps):
    outs = []
    for _ in range(steps):
        tok, cache = session.decode(cache, tok, pos)
        outs.append(tok[:, 0])           # stays on device
    return np.asarray(jnp.stack(outs))   # one fetch at the boundary


def timed(jit_step, x, iters):
    t = 0.0
    for _ in range(iters):
        y = jit_step(x)
        y.block_until_ready()            # explicit timing loop: exempt
        t = float(y[0])
    return t
