"""Pre-PR-3-style grouped checkpoint fixture: generator + restore smoke.

Before PR 3, ``TrainState`` had no ``plans`` field, so grouped checkpoints
recorded only ``params``/``opt``/``step`` leaves. Restoring one into a
modern grouped ``TrainState`` (whose target tree carries GroupPlan leaves)
used to raise ``KeyError``; ``repro.train.state.restore_state`` now
migrates such manifests and re-encodes the plans from the restored params.

The checked-in fixture lives next to this file
(``prepr3_grouped_ckpt/``) and is what the CI restore-migration smoke and
``tests/test_restore.py`` restore from. Saving ``state._replace(plans=())``
produces a manifest byte-layout-identical to the pre-PR-3 era — the empty
tuple contributes no leaves.

Regenerate (after a param-tree change) with:

    PYTHONPATH=src python tests/fixtures/prepr3_ckpt.py --write

Run the restore-migration smoke (what CI does) with:

    PYTHONPATH=src python tests/fixtures/prepr3_ckpt.py --smoke
"""
import argparse
import pathlib

import jax
import jax.numpy as jnp

FIXTURE_DIR = pathlib.Path(__file__).resolve().parent / "prepr3_grouped_ckpt"
FIXTURE_STEP = 2
SEED = 7


def tiny_cfg():
    """The grouped LM config the fixture was saved from (mixer FLGW on)."""
    from repro.models.config import ModelConfig
    return ModelConfig(
        name="prepr3_fixture", family="dense", n_layers=2, d_model=32,
        n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
        flgw_groups=4, flgw_path="grouped", flgw_targets=("mlp", "attn"),
        dtype=jnp.float32, remat=False)


def init_fixture_state():
    from repro.train import state as state_lib
    return state_lib.init_state(jax.random.PRNGKey(SEED), tiny_cfg(),
                                optimizer="rmsprop")


def write_fixture(ckpt_dir=FIXTURE_DIR) -> str:
    """Save the pre-PR-3-shaped checkpoint (no plans leaves)."""
    from repro.checkpoint import save_checkpoint
    state = init_fixture_state()
    state = state._replace(plans=(),
                           step=jnp.full((), FIXTURE_STEP, jnp.int32))
    path = save_checkpoint(ckpt_dir, FIXTURE_STEP, state)
    print(f"wrote pre-plans grouped fixture at {path}")
    return path


def restore_smoke(ckpt_dir=FIXTURE_DIR) -> None:
    """Restore the fixture through the migrating path and sanity-check."""
    import numpy as np

    from repro.core import encoder
    from repro.core.flgw import FLGWConfig
    from repro.train import state as state_lib

    cfg = tiny_cfg()
    target = init_fixture_state()
    restored, step = state_lib.restore_state(ckpt_dir, target, cfg)
    assert step == FIXTURE_STEP, step
    assert int(restored.step) == FIXTURE_STEP, restored.step
    assert isinstance(restored.plans, encoder.PlanState), type(restored.plans)
    fresh = encoder.encode_plans(
        restored.params, FLGWConfig(groups=cfg.flgw_groups,
                                    path=cfg.flgw_path))
    for a, b in zip(jax.tree.leaves(restored.plans), jax.tree.leaves(fresh)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    n = sum(1 for _ in encoder.iter_flgw_layers(restored.params))
    print(f"restore-migration smoke OK: step {step}, {n} FLGW layers "
          "re-encoded from restored params")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true",
                    help="(re)generate the checked-in fixture")
    ap.add_argument("--smoke", action="store_true",
                    help="restore the fixture via the migrating path")
    ap.add_argument("--ckpt-dir", default=str(FIXTURE_DIR))
    args = ap.parse_args(argv)
    if args.write:
        write_fixture(args.ckpt_dir)
    if args.smoke or not args.write:
        restore_smoke(args.ckpt_dir)


if __name__ == "__main__":
    main()
