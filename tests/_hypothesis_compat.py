"""Use hypothesis when installed; fall back to deterministic sampling.

The property tests only need ``@given`` with ``st.integers`` /
``st.sampled_from`` and ``@settings(max_examples=..., deadline=None)``.
When the real ``hypothesis`` package is available (CI installs it via the
``test`` extra) it is re-exported unchanged. Otherwise this module provides
a minimal stand-in that runs each property on a fixed-seed random sample of
the strategy space — fewer examples, no shrinking, but the invariants still
execute everywhere the bare runtime deps are installed.
"""
from __future__ import annotations

import functools
import random

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis missing
    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5  # keep the dependency-free path fast

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mimics `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

    def settings(max_examples=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                limit = getattr(wrapper, "_max_examples", None) \
                    or getattr(fn, "_max_examples", None) or 10
                rng = random.Random(0x5EED)
                for _ in range(min(limit, _FALLBACK_EXAMPLES)):
                    fn(**{k: s.draw(rng) for k, s in strats.items()})
            # pytest must see the zero-arg signature, not fn's via __wrapped__
            del wrapper.__wrapped__
            return wrapper
        return deco
