"""Serving-side plan staleness (the last ROADMAP encoder follow-up).

``init_cache(params=...)`` encodes the serving PlanState once and every
decode step trusts ``cache["plans"]`` — correct while params are frozen,
wrong the moment online tuning moves them *between* requests: the grouped
kernels would decode against metadata of weights that no longer exist.
These tests pin the fix: the prefill/serve boundary certifies the cached
plans via ``plan_signature`` and re-encodes iff stale. They fail on the
pre-fix code (prefill consumed caller plans unconditionally; no boundary
hook existed).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import encoder
from repro.models import transformer
from repro.serving import make_decode_step, make_prefill_step


def _cfg():
    return registry.get_smoke_config("gemma2_2b", flgw_groups=4,
                                     flgw_path="grouped",
                                     flgw_targets=("mlp",))


def _flip_grouping(params):
    """Simulated online-tuning update that moves every balanced-deal
    layout: negating ig/og swaps each row/col's argmax preference."""
    flipped = jax.tree.map(lambda x: x, params)      # fresh containers
    for _, p in encoder.iter_flgw_layers(flipped):
        p["ig"] = -p["ig"]
        p["og"] = -p["og"]
    return flipped


def _batch(b=1, s=8, vocab=128):
    toks = jax.random.randint(jax.random.PRNGKey(9), (b, s), 0, vocab,
                              jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return {"tokens": toks, "positions": pos}


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    params, _ = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    cache = transformer.init_cache(cfg, 1, 8, params=params)
    assert isinstance(cache["plans"], encoder.PlanState)
    return cfg, params, cache


def test_refresh_cache_plans_fires_and_matches_fresh_encode(served):
    """Params mutated between requests: the boundary hook must detect the
    moved layout and hand back exactly a fresh encode's PlanState."""
    cfg, params, cache = served
    serve = jax.jit(make_decode_step(cfg))
    tok = jnp.zeros((1, 1), jnp.int32)
    pos = jnp.zeros((1, 1), jnp.int32)
    _, cache = serve(params, cache, tok, pos)        # request 1 decodes

    params2 = _flip_grouping(params)                 # online tuning
    refreshed = transformer.refresh_cache_plans(params2, cfg, cache)
    # init_cache attaches compact weights (the fused-path operand), so the
    # refresh hands back a fresh encode with wc re-gathered from params2
    fresh = encoder.attach_compact(
        transformer.encode_plans(params2, cfg), params2)
    # the refresh fired: new signature, different from the stale one...
    assert int(refreshed["plans"].sig) == int(fresh.sig)
    assert int(refreshed["plans"].sig) != int(cache["plans"].sig)
    # ...and the plans are bitwise a fresh encode
    for a, b in zip(jax.tree.leaves(refreshed["plans"]),
                    jax.tree.leaves(fresh)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # KV buffers ride through untouched
    for a, b in zip(jax.tree.leaves(refreshed["blocks"]),
                    jax.tree.leaves(cache["blocks"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_refresh_cache_plans_is_a_noop_when_params_unchanged(served):
    """No layout movement ⇒ the cached plans pass through bitwise (the
    amortization contract: half a signature pass, zero encodes)."""
    cfg, params, cache = served
    same = transformer.refresh_cache_plans(params, cfg, dict(cache))
    for a, b in zip(jax.tree.leaves(same["plans"]),
                    jax.tree.leaves(cache["plans"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_refresh_cache_plans_passes_planless_cache_through():
    cfg = _cfg().with_updates(flgw_groups=1, flgw_path="masked")
    cache = transformer.init_cache(cfg, 1, 8)
    assert cache["plans"] == ()
    same = transformer.refresh_cache_plans({}, cfg, cache)
    assert same["plans"] == ()


def test_prefill_certifies_caller_supplied_plans(served):
    """The prefill boundary must no longer trust a caller-passed PlanState:
    stale plans (encoded from the pre-update params) must produce the same
    logits as a fresh encode. Fails pre-fix, where prefill consumed them
    unconditionally."""
    cfg, params, cache = served
    params2 = _flip_grouping(params)
    stale = cache["plans"]                 # encoded from `params`
    fresh = transformer.encode_plans(params2, cfg)
    batch = _batch(vocab=cfg.vocab)
    prefill = make_prefill_step(cfg)
    out_certified = prefill(params2, batch, plans=stale)
    out_fresh = prefill(params2, batch, plans=fresh)
    np.testing.assert_array_equal(np.asarray(out_certified),
                                  np.asarray(out_fresh))
    # the guard is meaningful: consuming the stale plans raw DOES change
    # the forward (this is exactly the pre-fix serving corruption)
    h_stale, _, _ = transformer.lm_apply(
        params2, cfg, batch["tokens"], batch["positions"],
        plans=stale.plans, return_hidden=True)
    h_fresh, _, _ = transformer.lm_apply(
        params2, cfg, batch["tokens"], batch["positions"],
        plans=fresh.plans, return_hidden=True)
    assert not np.allclose(np.asarray(h_stale), np.asarray(h_fresh))


def test_serve_step_refresh_plans_flag_heals_a_stale_cache(served):
    """make_decode_step(certify_each_step=True) builds the certification into
    every decode step: a stale cache decodes identically to one freshly
    encoded from the updated params; the default step (trusting the
    cache) does not."""
    cfg, params, cache0 = served
    params2 = _flip_grouping(params)
    stale_cache = transformer.init_cache(cfg, 1, 8, params=params)
    fresh_cache = transformer.init_cache(cfg, 1, 8, params=params2)
    tok = jnp.zeros((1, 1), jnp.int32)
    pos = jnp.zeros((1, 1), jnp.int32)

    healing = jax.jit(make_decode_step(cfg, certify_each_step=True))
    t_healed, c_healed = healing(params2, stale_cache, tok, pos)
    t_fresh, c_fresh = healing(params2, fresh_cache, tok, pos)
    np.testing.assert_array_equal(np.asarray(t_healed), np.asarray(t_fresh))
    assert int(c_healed["plans"].sig) == int(c_fresh["plans"].sig)
    for a, b in zip(jax.tree.leaves(c_healed["blocks"]),
                    jax.tree.leaves(c_fresh["blocks"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    trusting = jax.jit(make_decode_step(cfg))
    stale_cache2 = transformer.init_cache(cfg, 1, 8, params=params)
    _, c_trust = trusting(params2, stale_cache2, tok, pos)
    assert int(c_trust["plans"].sig) != int(c_fresh["plans"].sig)
