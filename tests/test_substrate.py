"""Substrate tests: sharding rules, checkpoint, data pipeline, optimizers,
loss, load balancing (Table I), runtime fault handling."""
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint import (latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.core import flgw
from repro.core.load_balance import (balanced_allocate, deviation,
                                     row_allocate, threshold_allocate)
from repro.data.pipeline import SyntheticTokens, make_batch_iterator
from repro.optim.optimizers import (adamw, adamw_init, clip_by_global_norm,
                                    global_norm, rmsprop, rmsprop_init)
from repro.runtime.fault import retry_transient
from repro.sharding import partition
from repro.train.loss import chunked_cross_entropy


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def _mesh2():
    import numpy as np
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(devs, ("data", "model"))


def test_constrained_pspec_drops_nondivisible_axes():
    mesh = _mesh2()
    # 1-wide axes always divide: spec survives
    assert partition.constrained_pspec(("batch", None), (8, 4), mesh) == \
        P("data")
    # unknown names replicate
    assert partition.constrained_pspec(("nope",), (8,), mesh) == P()


def test_constrained_pspec_divisibility_on_fake_mesh():
    """Resolution logic against a virtual 16-wide axis (no devices needed:
    we only exercise the pure function via a Mesh of shape attributes)."""
    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)

    fm = FakeMesh()
    # kv_heads = 8 on a 16-wide model axis -> dropped
    assert partition.constrained_pspec(
        ("layers", "batch", "seq_kv", "kv_heads"), (4, 128, 4096, 8),
        fm) == P(None, "data", "model")
    # batch=1 cannot shard
    assert partition.constrained_pspec(("batch",), (1,), fm) == P()
    # two-axis batch: (pod, data) with pod missing -> data only
    assert partition.constrained_pspec(("batch",), (256,), fm) == P("data")


def test_logical_rules_one_axis_per_tensor():
    class FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)

    # "ffn" then "heads" both want model: second one must drop
    got = partition.constrained_pspec(("ffn", "heads"), (256, 256),
                                      FakeMesh())
    assert got == P("model")


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.zeros((), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t)
    got, step = restore_checkpoint(tmp_path, t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, jnp.float32),
                                      np.asarray(b, jnp.float32))
        assert a.dtype == b.dtype


def test_checkpoint_keeps_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, s, t, keep=2)
    assert latest_step(tmp_path) == 5
    from repro.checkpoint import list_steps
    assert list_steps(tmp_path) == [4, 5]


def test_checkpoint_ignores_partial_tmp(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 1, t)
    # simulate a crash mid-write: stale tmp dir with garbage
    bad = pathlib.Path(tmp_path) / "step_00000002.tmp-999-1"
    bad.mkdir()
    (bad / "arr_000000.npy").write_bytes(b"partial")
    assert latest_step(tmp_path) == 1
    got, step = restore_checkpoint(tmp_path, t)
    assert step == 1


def test_checkpoint_detects_corruption(tmp_path):
    t = _tree()
    path = save_checkpoint(tmp_path, 3, t)
    # flip bytes in one leaf
    f = sorted(pathlib.Path(path).glob("arr_*.npy"))[0]
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(IOError):
        restore_checkpoint(tmp_path, t)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_per_step():
    ds = SyntheticTokens(vocab=1000, batch=4, seq=16, seed=3)
    a = ds.batch_at(5)
    b = ds.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_targets_are_shifted_tokens():
    ds = SyntheticTokens(vocab=97, batch=2, seq=8, seed=0)
    b = ds.batch_at(0)
    assert b["tokens"].shape == (2, 8)
    assert (b["tokens"] < 97).all() and (b["tokens"] >= 0).all()


def test_data_iterator_resumes_at_step():
    ds = SyntheticTokens(vocab=50, batch=2, seq=4, seed=1)
    it = make_batch_iterator(ds, start_step=3)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], ds.batch_at(3)["tokens"])


# ---------------------------------------------------------------------------
# Optimizers / loss
# ---------------------------------------------------------------------------

def test_rmsprop_and_adamw_minimize_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p["x"] - target) ** 2)

    for name, init, step in (
            ("rmsprop", rmsprop_init,
             lambda p, g, s: rmsprop(p, g, s, lr=0.05)),
            ("adamw", adamw_init,
             lambda p, g, s: adamw(p, g, s, lr=0.05, weight_decay=0.0))):
        params = {"x": jnp.zeros(3)}
        state = init(params)
        for _ in range(300):
            g = jax.grad(loss)(params)
            params, state = step(params, g, state)
        assert float(loss(params)) < 1e-2, name


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_chunked_ce_matches_full_ce():
    key = jax.random.PRNGKey(0)
    b, s, d, v = 2, 16, 8, 32
    x = jax.random.normal(key, (b, s, d))
    emb = jax.random.normal(jax.random.fold_in(key, 1), (v, d))
    tgt = jax.random.randint(jax.random.fold_in(key, 2), (b, s), 0, v)
    got = chunked_cross_entropy(x, emb, tgt, chunk=4)
    logits = x @ emb.T
    logz = jax.nn.logsumexp(logits, -1)
    ll = jnp.take_along_axis(logits, tgt[..., None], -1)[..., 0]
    want = jnp.mean(logz - ll)
    assert float(got) == pytest.approx(float(want), rel=1e-5)


def test_chunked_ce_gradients_flow_to_embedding():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (2, 8, 4))
    emb = jax.random.normal(jax.random.fold_in(key, 1), (16, 4))
    tgt = jnp.zeros((2, 8), jnp.int32)
    g = jax.grad(lambda e: chunked_cross_entropy(x, e, tgt, chunk=4))(emb)
    assert float(jnp.abs(g).sum()) > 0


# ---------------------------------------------------------------------------
# Load balancing (Table I)
# ---------------------------------------------------------------------------

def test_row_allocation_beats_threshold_on_flgw_masks():
    """Paper Table I: row-based deviation < threshold-based, for G=2..16."""
    key = jax.random.PRNGKey(0)
    wins = 0
    cases = 0
    for g in (2, 4, 8, 16):
        for seed in range(5):
            k = jax.random.fold_in(key, g * 100 + seed)
            ig = jax.random.normal(k, (128, g))
            og = jax.random.normal(jax.random.fold_in(k, 1), (g, 512))
            ig_idx, og_idx = flgw.grouping_indices(ig, og)
            mask = np.asarray(flgw.mask_from_indices(ig_idx, og_idx))
            d_thr = deviation(threshold_allocate(mask, 3))
            d_row = deviation(row_allocate(mask, 3))
            cases += 1
            wins += d_row <= d_thr
    assert wins / cases >= 0.6   # row-based wins on average (paper: always)


def test_balanced_allocation_deviation_near_zero():
    """Our TPU scheme: capacity-balanced rows ⇒ ~0 deviation by design."""
    key = jax.random.PRNGKey(1)
    from repro.core.grouped import make_plan
    ig = jax.random.normal(key, (128, 4))
    og = jax.random.normal(jax.random.fold_in(key, 1), (4, 512))
    plan = make_plan(ig, og)
    per_core = balanced_allocate(np.asarray(plan.row_group),
                                 np.asarray(plan.col_group), 4, 4)
    ideal = per_core.sum() / 4
    assert deviation(per_core) <= 0.05 * ideal


# ---------------------------------------------------------------------------
# Runtime
# ---------------------------------------------------------------------------

def test_retry_transient_retries_then_succeeds():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("DEADLINE_EXCEEDED: collective timed out")
        return 42

    assert retry_transient(flaky, retries=5, backoff_s=0.0) == 42
    assert calls["n"] == 3


def test_retry_transient_raises_on_permanent():
    def broken():
        raise ValueError("shape mismatch")

    with pytest.raises(ValueError):
        retry_transient(broken, retries=3, backoff_s=0.0)


def test_elastic_remesh_roundtrip():
    from repro.runtime.elastic import remesh_state
    state = {"w": jnp.arange(8.0).reshape(2, 4)}
    specs = {"w": ("embed", "ffn")}
    new_state, mesh = remesh_state(state, specs)
    np.testing.assert_array_equal(np.asarray(new_state["w"]),
                                  np.asarray(state["w"]))
    assert set(mesh.axis_names) == {"data", "model"}
