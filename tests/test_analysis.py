"""The static-analysis + contracts subsystem (``repro.analysis``).

Two layers under test:

* the AST linter — every rule ANL001..ANL006 against its positive and
  negative fixture (``tests/fixtures/lint/``), plus the suppression
  machinery (per-line ``# noqa``, the committed baseline including
  stale-entry rot detection, CLI exits);
* the runtime contracts — ``trace_counter`` parity with the retired
  per-file counting monkeypatch, ``assert_max_traces``, and
  ``no_retrace`` catching a deliberately shape-unstable jit loop.
"""
import os
import types

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import contracts
from repro.analysis.lint import (DEFAULT_EXCLUDES, Finding,
                                 apply_baseline, format_baseline_entry,
                                 lint_file, lint_paths, lint_source,
                                 load_baseline, main,
                                 stale_baseline_entries)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "lint")

# rule -> findings its positive fixture must produce (count pins the
# fixture corpus: every deliberate violation is caught, nothing extra)
EXPECTED = {"ANL001": 4, "ANL002": 5, "ANL003": 5, "ANL004": 4,
            "ANL005": 3, "ANL006": 2}


def _fixture(rule: str, kind: str) -> str:
    return os.path.join(FIXTURES, f"{rule.lower()}_{kind}.py")


# -- the rules, fixture by fixture -------------------------------------------

@pytest.mark.parametrize("rule", sorted(EXPECTED))
def test_positive_fixture_fires_only_its_rule(rule):
    findings = lint_file(_fixture(rule, "bad"))
    assert findings, f"{rule} positive fixture produced no findings"
    assert {f.code for f in findings} == {rule}
    assert len(findings) == EXPECTED[rule]


@pytest.mark.parametrize("rule", sorted(EXPECTED))
def test_negative_fixture_is_clean_across_all_rules(rule):
    assert lint_file(_fixture(rule, "good")) == []


def test_anl001_pins_the_pr8_lockout_regression():
    """The exact PR-8 failure shape: a module-level jnp constant in a
    module whose main() calls jax.distributed.initialize."""
    findings = lint_file(_fixture("ANL001", "bad"))
    lines = {f.line: f for f in findings}
    src = open(_fixture("ANL001", "bad")).read().splitlines()
    flagged = [src[ln - 1] for ln in lines]
    assert any("jnp.arange" in s for s in flagged)
    assert any("jax.random.PRNGKey" in s for s in flagged)
    # ...and the fixture really contains the doomed initialize call
    assert any("jax.distributed.initialize" in s for s in src)


def test_anl001_needs_importability():
    """tests/benchmarks scripts (no sibling __init__.py) are exempt —
    they run top to bottom, import-time arrays are their job."""
    src = "import jax.numpy as jnp\nX = jnp.zeros((2,))\n"
    assert lint_source(src, importable=True)
    assert lint_source(src, importable=False) == []


def test_select_restricts_rules():
    findings = lint_file(_fixture("ANL002", "bad"), select=["ANL001"])
    assert findings == []


# -- suppression: noqa + baseline --------------------------------------------

def test_noqa_suppresses_matching_code_only():
    base = "import jax.numpy as jnp\nX = jnp.zeros((2,))"
    assert lint_source(base + "  # noqa: ANL001\n", importable=True) == []
    assert lint_source(base + "  # noqa\n", importable=True) == []
    assert lint_source(base + "  # noqa: ANL003\n", importable=True)
    assert lint_source(
        base + "  # noqa: ANL003, ANL001\n", importable=True) == []


def test_baseline_roundtrip(tmp_path):
    findings = lint_file(_fixture("ANL005", "bad"))
    bl = tmp_path / "baseline.txt"
    bl.write_text("# why: fixture corpus, accepted\n" + "\n".join(
        format_baseline_entry(f) for f in findings) + "\n")
    loaded = load_baseline(str(bl))
    assert sum(loaded.values()) == len(findings)
    new, old = apply_baseline(findings, loaded)
    assert new == [] and len(old) == len(findings)
    # an extra finding not covered by the baseline stays new
    extra = Finding("x.py", 1, 0, "ANL005", "m", "src-line")
    new, _ = apply_baseline(findings + [extra], loaded)
    assert new == [extra]


def test_stale_baseline_entries_detects_rot():
    findings = lint_file(_fixture("ANL005", "bad"))
    loaded = load_baseline(os.devnull)
    for f in findings:
        loaded[f.baseline_key()] += 1
    assert stale_baseline_entries(findings, loaded) == []
    ghost = ("gone.py", "ANL005", "x = removed_code()")
    loaded[ghost] += 1
    assert stale_baseline_entries(findings, loaded) == [ghost]
    # a narrowed --select that never ran the entry's rule is not rot
    assert stale_baseline_entries(findings, loaded,
                                  select=["ANL001"]) == []


def test_stale_baseline_entry_fails_check(tmp_path, capsys):
    bad = _fixture("ANL001", "bad")
    bl = tmp_path / "bl.txt"
    assert main([bad, "--write-baseline", "--baseline", str(bl),
                 "--no-default-excludes"]) == 0
    assert main([bad, "--check", "--baseline", str(bl),
                 "--no-default-excludes"]) == 0
    # an entry matching no finding turns --check red until deleted
    with open(bl, "a", encoding="utf-8") as fh:
        fh.write("gone.py|ANL001|X = jnp.zeros((2,))\n")
    assert main([bad, "--check", "--baseline", str(bl),
                 "--no-default-excludes"]) == 1
    assert "stale" in capsys.readouterr().out


def test_anl006_requires_registration_in_file_or_sibling_audit():
    # the shipped kernels register via sibling audit.py modules: the
    # whole src tree must be ANL006-clean
    src_root = os.path.join(os.path.dirname(__file__), "..", "src")
    assert lint_paths([src_root], select=["ANL006"]) == []
    # a pallas_call module with no registration anywhere fires per site
    findings = lint_file(_fixture("ANL006", "bad"))
    assert [f.code for f in findings] == ["ANL006", "ANL006"]


def test_cli_exit_codes(tmp_path, capsys):
    bad = _fixture("ANL001", "bad")
    # fixtures are default-excluded: the repo-wide invocation stays clean
    assert main([bad, "--no-baseline", "--check"]) == 0
    # --no-default-excludes turns the same invocation red
    assert main([bad, "--no-baseline", "--check",
                 "--no-default-excludes"]) == 1
    # a baseline covering every finding turns it green again
    bl = tmp_path / "bl.txt"
    assert main([bad, "--write-baseline", "--baseline", str(bl),
                 "--no-default-excludes"]) == 0
    assert main([bad, "--check", "--baseline", str(bl),
                 "--no-default-excludes"]) == 0
    capsys.readouterr()


def test_default_excludes_cover_the_fixture_corpus():
    findings = lint_paths([FIXTURES])
    assert findings == []
    assert lint_paths([FIXTURES], excludes=())
    assert any("fixtures" in x for x in DEFAULT_EXCLUDES)


def test_syntax_error_reports_anl000():
    findings = lint_source("def broken(:\n", "broken.py")
    assert [f.code for f in findings] == ["ANL000"]


# -- contracts: trace_counter ------------------------------------------------

def _fake_module():
    ns = types.SimpleNamespace()
    ns.__name__ = "fake"
    ns.make_plan = lambda a, b: (a, b)
    return ns


def test_trace_counter_counts_and_restores():
    mod = _fake_module()
    real = mod.make_plan
    with contracts.trace_counter(mod, "make_plan") as calls:
        assert mod.make_plan(1, 2) == (1, 2)   # delegates
        mod.make_plan(3, 4)
        assert calls.count == 2 and int(calls) == 2
        calls.reset()                          # the mid-test reset idiom
        mod.make_plan(5, 6)
        assert calls.count == 1
    assert mod.make_plan is real               # restored on exit


def test_trace_counter_restores_on_exception():
    mod = _fake_module()
    real = mod.make_plan
    with pytest.raises(RuntimeError):
        with contracts.trace_counter(mod, "make_plan"):
            raise RuntimeError("boom")
    assert mod.make_plan is real


def test_trace_counter_records_args():
    mod = _fake_module()
    with contracts.trace_counter(mod, "make_plan",
                                 record_args=True) as calls:
        mod.make_plan(1, b=2)
    assert calls.calls == [((1,), {"b": 2})]


def test_trace_counter_counts_traces_like_the_old_idiom():
    """Parity with the retired monkeypatch: calls under jax tracing
    (eval_shape) count — the number of traces IS the contract."""
    mod = _fake_module()
    mod.make_plan = lambda x: x * 2.0
    with contracts.trace_counter(mod, "make_plan") as calls:
        jax.eval_shape(lambda x: mod.make_plan(x) + mod.make_plan(x),
                       jnp.zeros((3,)))
    assert calls.count == 2


def test_assert_max_traces():
    mod = _fake_module()
    with contracts.assert_max_traces(mod, "make_plan", 2):
        mod.make_plan(1, 2)
    with pytest.raises(contracts.ContractViolation, match="at most 1"):
        with contracts.assert_max_traces(mod, "make_plan", 1):
            mod.make_plan(1, 2)
            mod.make_plan(3, 4)
    with pytest.raises(contracts.ContractViolation, match="exactly 2"):
        with contracts.assert_max_traces(mod, "make_plan", 2,
                                         exactly=True):
            mod.make_plan(1, 2)


# -- contracts: no_retrace ---------------------------------------------------

def test_no_retrace_catches_shape_unstable_loop():
    """The deliberate violation: one jitted function fed a different
    shape every iteration recompiles per step — exactly the silent
    serving-stall class the Engine/async debug_contracts hook guards."""
    @jax.jit
    def unstable_step(x):
        return x * 2.0

    with pytest.raises(contracts.RetraceError, match="unstable_step"):
        with contracts.no_retrace(label="unit"):
            for n in range(1, 4):
                unstable_step(jnp.zeros((n,)))


def test_no_retrace_passes_shape_stable_loop():
    @jax.jit
    def stable_step(x):
        return x + 1.0

    with contracts.no_retrace() as mon:
        for _ in range(5):
            stable_step(jnp.zeros((3,)))
    counts = mon.counts()
    assert all(n <= 1 for n in counts.values())


def test_no_retrace_allowlist_and_monitor():
    @jax.jit
    def allowed_poly(x):
        return x - 1.0

    with contracts.no_retrace(allow=("allowed_poly",)) as mon:
        for n in range(1, 4):
            allowed_poly(jnp.zeros((n,)))
    assert mon.counts().get("allowed_poly", 0) >= 2  # seen but exempt
