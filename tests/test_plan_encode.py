"""Plan-encode kernel: balanced-assign invariants + bitwise kernel parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.plan_encode import ops as pe_ops
from repro.kernels.plan_encode import ref as pe_ref

IMPLS = ("reference", "pallas")


def _scores(seed, m, g):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, g))


def _assign(scores, slack, impl):
    return np.asarray(pe_ops.balanced_assign(scores, axis=1, slack=slack,
                                             impl=impl))


@settings(max_examples=20, deadline=None)
@given(m=st.integers(4, 96), g=st.sampled_from([2, 4, 8]),
       slack=st.sampled_from([1.0, 1.25, 1.5]),
       seed=st.integers(0, 2**31 - 1))
def test_output_is_permutation_with_padding(m, g, seed, slack):
    """Every item appears exactly once; padding slots hold the sentinel m."""
    for impl in IMPLS:
        ids = _assign(_scores(seed, m, g), slack, impl)
        cap = pe_ref.compute_cap(m, g, slack)
        assert ids.shape == (g, cap)
        valid = ids[ids < m]
        assert sorted(valid.tolist()) == list(range(m))
        assert (ids[ids >= m] == m).all()


@settings(max_examples=20, deadline=None)
@given(m=st.integers(4, 96), g=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31 - 1))
def test_zero_capacity_deviation_at_slack_one(m, g, seed):
    """slack=1.0 reproduces the strict equal-deal: group loads deviate only
    by the ceil-padding (zero when g divides m) — the paper's balanced
    workload, by construction."""
    for impl in IMPLS:
        ids = _assign(_scores(seed, m, g), 1.0, impl)
        loads = (ids < m).sum(axis=1)
        assert loads.sum() == m
        if m % g == 0:
            assert (loads == m // g).all()      # zero deviation
        else:
            assert loads.max() - loads.min() <= 1 + (g * (-(-m // g)) - m)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(8, 96), g=st.sampled_from([2, 4]),
       slack=st.sampled_from([1.0, 1.25]),
       seed=st.integers(0, 2**31 - 1))
def test_overflow_spills_only_least_confident(m, g, seed, slack):
    """An over-popular group keeps its cap most-confident preferrers; only
    the tail spills to other groups' free slots."""
    scores = _scores(seed, m, g)
    pref = np.asarray(jnp.argmax(scores, axis=1))
    strength = np.asarray(jnp.max(scores, axis=1))
    for impl in IMPLS:
        ids = _assign(scores, slack, impl)
        cap = ids.shape[1]
        for gi in range(g):
            members = np.where(pref == gi)[0]
            if len(members) <= cap:
                continue
            # top-cap by (strength desc, index asc) — the lexsort order
            order = members[np.lexsort((members, -strength[members]))]
            expect_kept = set(order[:cap].tolist())
            got_kept = set(int(i) for i in ids[gi] if i < m)
            assert got_kept == expect_kept


@settings(max_examples=15, deadline=None)
@given(m=st.integers(8, 96), g=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31 - 1))
def test_slack_keeps_more_argmax_preferences(m, g, seed):
    """The capacity-factor trade: slack headroom lets more items stay in
    their argmax group (never fewer)."""
    scores = _scores(seed, m, g)
    pref = np.asarray(jnp.argmax(scores, axis=1))

    def n_kept(slack, impl):
        ids = _assign(scores, slack, impl)
        kept = 0
        for gi in range(g):
            kept += sum(1 for i in ids[gi] if i < m and pref[i] == gi)
        return kept

    for impl in IMPLS:
        assert n_kept(1.5, impl) >= n_kept(1.25, impl) >= n_kept(1.0, impl)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 160), g=st.sampled_from([2, 3, 4, 8, 16]),
       slack=st.sampled_from([1.0, 1.25, 1.5, 2.0]),
       seed=st.integers(0, 2**31 - 1))
def test_kernel_bitwise_matches_lexsort_reference(m, g, seed, slack):
    """The acceptance bar: the counting-sort kernel reproduces the lexsort
    reference bit for bit, including slack>1 spill ordering."""
    scores = _scores(seed, m, g)
    ref = np.asarray(pe_ref.ref_balanced_assign(scores, slack))
    got = _assign(scores, slack, "pallas")
    np.testing.assert_array_equal(got, ref)


def test_batched_encode_matches_per_layer_loop():
    """Leading (stacked-layer) dims fold into the kernel grid."""
    key = jax.random.PRNGKey(7)
    scores = jax.random.normal(key, (3, 40, 4))
    got = np.asarray(pe_ops.balanced_assign(scores, axis=1, slack=1.25))
    want = np.stack([np.asarray(pe_ref.ref_balanced_assign(scores[i], 1.25))
                     for i in range(3)])
    np.testing.assert_array_equal(got, want)


def test_axis0_matches_transposed_axis1():
    """balanced_assign(og, axis=0) == balanced_assign(og.T, axis=1) — the
    identity the transpose-plan trick rests on."""
    key = jax.random.PRNGKey(11)
    og = jax.random.normal(key, (4, 56))
    a0 = np.asarray(pe_ops.balanced_assign(og, axis=0, slack=1.25))
    a1 = np.asarray(pe_ops.balanced_assign(og.T, axis=1, slack=1.25))
    np.testing.assert_array_equal(a0, a1)


# ---------------------------------------------------------------------------
# Implementation-selection policy (resolve_impl)
# ---------------------------------------------------------------------------

def test_resolve_impl_policy():
    """The single impl-selection policy, exposed for tests: explicit
    choices bind, the shared reference switch and the size cap drive the
    implicit fallbacks."""
    import repro.kernels as kernels_mod
    big = pe_ops._MAX_ITEMS + 1
    assert pe_ops.resolve_impl(64) == "pallas"
    assert pe_ops.resolve_impl(64, "pallas") == "pallas"
    assert pe_ops.resolve_impl(64, "reference") == "reference"
    assert pe_ops.resolve_impl(big, "reference") == "reference"
    with kernels_mod.use_reference_impl():
        assert pe_ops.resolve_impl(64) == "reference"
        # explicit choice beats the ambient switch
        assert pe_ops.resolve_impl(64, "pallas") == "pallas"
    pe_ops.reset_size_fallback_warning(True)  # silence for this check
    assert pe_ops.resolve_impl(big) == "reference"
    with pytest.raises(ValueError, match="impl must be"):
        pe_ops.resolve_impl(64, "mystery")


def test_explicit_pallas_above_cap_raises():
    """impl='pallas' is a contract, not a hint: above the VMEM tile cap it
    must raise a pointed error instead of silently running the lexsort
    reference (the pre-fix behavior, which made kernel perf runs lie)."""
    big = pe_ops._MAX_ITEMS + 8
    scores = jnp.zeros((big, 4))
    with pytest.raises(ValueError, match="_MAX_ITEMS"):
        pe_ops.balanced_assign(scores, axis=1, impl="pallas")
    # axis=0 counts columns as items
    with pytest.raises(ValueError, match="_MAX_ITEMS"):
        pe_ops.balanced_assign(jnp.zeros((4, big)), axis=0, impl="pallas")
    # ...and under the cap the explicit request is honoured
    assert pe_ops.resolve_impl(pe_ops._MAX_ITEMS, "pallas") == "pallas"


def test_implicit_size_fallback_warns_once_and_matches_reference():
    """Implicit oversize encodes fall back to the reference with ONE
    RuntimeWarning per process — and stay bitwise-identical to it."""
    import warnings as w
    big = pe_ops._MAX_ITEMS + 8
    scores = jax.random.normal(jax.random.PRNGKey(3), (big, 4))
    # re-arm the latch; the autouse conftest fixture restores it after
    pe_ops.reset_size_fallback_warning()
    with pytest.warns(RuntimeWarning, match="lexsort reference"):
        got = pe_ops.balanced_assign(scores, axis=1)
    assert pe_ops.size_fallback_warned()
    ref = np.asarray(pe_ref.ref_balanced_assign(scores, 1.0))
    np.testing.assert_array_equal(np.asarray(got), ref)
    with w.catch_warnings(record=True) as caught:
        w.simplefilter("always")
        pe_ops.balanced_assign(scores * 2.0, axis=1)
    assert not any(issubclass(c.category, RuntimeWarning)
                   for c in caught), caught
