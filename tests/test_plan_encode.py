"""Plan-encode kernel: balanced-assign invariants + bitwise kernel parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.plan_encode import ops as pe_ops
from repro.kernels.plan_encode import ref as pe_ref

IMPLS = ("reference", "pallas")


def _scores(seed, m, g):
    return jax.random.normal(jax.random.PRNGKey(seed), (m, g))


def _assign(scores, slack, impl):
    return np.asarray(pe_ops.balanced_assign(scores, axis=1, slack=slack,
                                             impl=impl))


@settings(max_examples=20, deadline=None)
@given(m=st.integers(4, 96), g=st.sampled_from([2, 4, 8]),
       slack=st.sampled_from([1.0, 1.25, 1.5]),
       seed=st.integers(0, 2**31 - 1))
def test_output_is_permutation_with_padding(m, g, seed, slack):
    """Every item appears exactly once; padding slots hold the sentinel m."""
    for impl in IMPLS:
        ids = _assign(_scores(seed, m, g), slack, impl)
        cap = pe_ref.compute_cap(m, g, slack)
        assert ids.shape == (g, cap)
        valid = ids[ids < m]
        assert sorted(valid.tolist()) == list(range(m))
        assert (ids[ids >= m] == m).all()


@settings(max_examples=20, deadline=None)
@given(m=st.integers(4, 96), g=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31 - 1))
def test_zero_capacity_deviation_at_slack_one(m, g, seed):
    """slack=1.0 reproduces the strict equal-deal: group loads deviate only
    by the ceil-padding (zero when g divides m) — the paper's balanced
    workload, by construction."""
    for impl in IMPLS:
        ids = _assign(_scores(seed, m, g), 1.0, impl)
        loads = (ids < m).sum(axis=1)
        assert loads.sum() == m
        if m % g == 0:
            assert (loads == m // g).all()      # zero deviation
        else:
            assert loads.max() - loads.min() <= 1 + (g * (-(-m // g)) - m)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(8, 96), g=st.sampled_from([2, 4]),
       slack=st.sampled_from([1.0, 1.25]),
       seed=st.integers(0, 2**31 - 1))
def test_overflow_spills_only_least_confident(m, g, seed, slack):
    """An over-popular group keeps its cap most-confident preferrers; only
    the tail spills to other groups' free slots."""
    scores = _scores(seed, m, g)
    pref = np.asarray(jnp.argmax(scores, axis=1))
    strength = np.asarray(jnp.max(scores, axis=1))
    for impl in IMPLS:
        ids = _assign(scores, slack, impl)
        cap = ids.shape[1]
        for gi in range(g):
            members = np.where(pref == gi)[0]
            if len(members) <= cap:
                continue
            # top-cap by (strength desc, index asc) — the lexsort order
            order = members[np.lexsort((members, -strength[members]))]
            expect_kept = set(order[:cap].tolist())
            got_kept = set(int(i) for i in ids[gi] if i < m)
            assert got_kept == expect_kept


@settings(max_examples=15, deadline=None)
@given(m=st.integers(8, 96), g=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31 - 1))
def test_slack_keeps_more_argmax_preferences(m, g, seed):
    """The capacity-factor trade: slack headroom lets more items stay in
    their argmax group (never fewer)."""
    scores = _scores(seed, m, g)
    pref = np.asarray(jnp.argmax(scores, axis=1))

    def n_kept(slack, impl):
        ids = _assign(scores, slack, impl)
        kept = 0
        for gi in range(g):
            kept += sum(1 for i in ids[gi] if i < m and pref[i] == gi)
        return kept

    for impl in IMPLS:
        assert n_kept(1.5, impl) >= n_kept(1.25, impl) >= n_kept(1.0, impl)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(2, 160), g=st.sampled_from([2, 3, 4, 8, 16]),
       slack=st.sampled_from([1.0, 1.25, 1.5, 2.0]),
       seed=st.integers(0, 2**31 - 1))
def test_kernel_bitwise_matches_lexsort_reference(m, g, seed, slack):
    """The acceptance bar: the counting-sort kernel reproduces the lexsort
    reference bit for bit, including slack>1 spill ordering."""
    scores = _scores(seed, m, g)
    ref = np.asarray(pe_ref.ref_balanced_assign(scores, slack))
    got = _assign(scores, slack, "pallas")
    np.testing.assert_array_equal(got, ref)


def test_batched_encode_matches_per_layer_loop():
    """Leading (stacked-layer) dims fold into the kernel grid."""
    key = jax.random.PRNGKey(7)
    scores = jax.random.normal(key, (3, 40, 4))
    got = np.asarray(pe_ops.balanced_assign(scores, axis=1, slack=1.25))
    want = np.stack([np.asarray(pe_ref.ref_balanced_assign(scores[i], 1.25))
                     for i in range(3)])
    np.testing.assert_array_equal(got, want)


def test_axis0_matches_transposed_axis1():
    """balanced_assign(og, axis=0) == balanced_assign(og.T, axis=1) — the
    identity the transpose-plan trick rests on."""
    key = jax.random.PRNGKey(11)
    og = jax.random.normal(key, (4, 56))
    a0 = np.asarray(pe_ops.balanced_assign(og, axis=0, slack=1.25))
    a1 = np.asarray(pe_ops.balanced_assign(og.T, axis=1, slack=1.25))
    np.testing.assert_array_equal(a0, a1)


# ---------------------------------------------------------------------------
# Tiled placement: multi-tile parity (the lifted 4096 cap)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(m=st.integers(130, 520), g=st.sampled_from([2, 4, 8]),
       slack=st.sampled_from([1.0, 1.25, 1.5, 2.0]),
       seed=st.integers(0, 2**31 - 1))
def test_multi_tile_bitwise_matches_lexsort(m, g, seed, slack):
    """Forced 128-item tiles drive the tiled two-pass placement (rank
    accumulation across (bi, bj) tile pairs + cross-tile histogram prefix
    sums) under CPU interpret mode: still bitwise vs the lexsort,
    including slack>1 spill ordering across tile boundaries."""
    scores = _scores(seed, m, g)
    ref = np.asarray(pe_ref.ref_balanced_assign(scores, slack))
    got = np.asarray(pe_ops.balanced_assign(scores, axis=1, slack=slack,
                                            impl="pallas", block=128))
    np.testing.assert_array_equal(got, ref)


def test_cross_tile_spill_ordering_exact():
    """Adversarial over-popularity: most items prefer group 0, so spills
    chain across many tiles and groups — the overflow ranks must still
    land every item in the lexsort's exact slot."""
    key = jax.random.PRNGKey(17)
    m, g = 640, 4
    scores = jax.random.normal(key, (m, g))
    # bias ~70% of items toward group 0 (spread over all tiles)
    bias = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.7, (m,))
    scores = scores.at[:, 0].add(jnp.where(bias, 10.0, 0.0))
    for slack in (1.0, 1.3, 2.0):
        ref = np.asarray(pe_ref.ref_balanced_assign(scores, slack))
        got = np.asarray(pe_ops.balanced_assign(
            scores, axis=1, slack=slack, impl="pallas", block=128))
        np.testing.assert_array_equal(got, ref)


def test_oversize_encode_runs_kernel_bitwise():
    """M > 4096 — the old ``_MAX_ITEMS`` wall — now runs the Pallas
    kernel (explicitly pinned: no fallback, no warning) and stays bitwise
    vs the lexsort."""
    m, g, slack = 4352, 8, 1.3
    scores = _scores(23, m, g)
    ref = np.asarray(pe_ref.ref_balanced_assign(scores, slack))
    got = np.asarray(pe_ops.balanced_assign(scores, axis=1, slack=slack,
                                            impl="pallas"))
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# Implementation-selection policy (resolve_impl)
# ---------------------------------------------------------------------------

def test_resolve_impl_policy():
    """The single impl-selection policy, exposed for tests: explicit
    choices bind; the shared reference switch drives the only implicit
    fallback. Size no longer plays: the tiled placement has no cap."""
    import repro.kernels as kernels_mod
    assert pe_ops.resolve_impl(64) == "pallas"
    assert pe_ops.resolve_impl(64, "pallas") == "pallas"
    assert pe_ops.resolve_impl(64, "reference") == "reference"
    # the old _MAX_ITEMS wall is gone: oversize stays on the kernel
    assert pe_ops.resolve_impl(1 << 20) == "pallas"
    assert pe_ops.resolve_impl(1 << 20, "pallas") == "pallas"
    with kernels_mod.use_reference_impl():
        assert pe_ops.resolve_impl(64) == "reference"
        # explicit choice beats the ambient switch
        assert pe_ops.resolve_impl(64, "pallas") == "pallas"
    with pytest.raises(ValueError, match="impl must be"):
        pe_ops.resolve_impl(64, "mystery")


def test_size_fallback_machinery_retired():
    """The oversize latch (`size_fallback_warned`) and its warning are
    gone with the cap — the module no longer exposes them."""
    assert not hasattr(pe_ops, "_MAX_ITEMS")
    assert not hasattr(pe_ops, "size_fallback_warned")
    assert not hasattr(pe_ops, "reset_size_fallback_warning")
