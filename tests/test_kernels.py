"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.grouped import make_plan
from repro.kernels.flgw_matmul import ops as fops
from repro.kernels.flgw_matmul import ref as fref
from repro.kernels.flgw_matmul.flgw_matmul import grouped_bmm
from repro.kernels.osel_encode import ops as oops
from repro.kernels.osel_encode import ref as oref
from repro.kernels.osel_encode.osel_encode import encode_mask


def _tol(dtype):
    # f32: accumulation-order differences between the tiled kernel and a
    # single einsum reach ~1e-5 absolute on 256-deep contractions.
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=5e-5)


# ---------------------------------------------------------------------------
# grouped_bmm: the raw Pallas block-diagonal matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g,b,m,n", [
    (1, 8, 128, 128), (4, 16, 128, 256), (8, 128, 256, 128),
    (2, 8, 384, 128), (16, 8, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_bmm_matches_einsum(g, b, m, n, dtype):
    key = jax.random.PRNGKey(g * 1000 + b + m + n)
    xg = jax.random.normal(key, (g, b, m), jnp.float32).astype(dtype)
    wc = jax.random.normal(jax.random.fold_in(key, 1), (g, m, n),
                           jnp.float32).astype(dtype)
    bb = min(128, b)
    got = grouped_bmm(xg, wc, bb=bb, bn=128, bk=128, interpret=True)
    want = fref.ref_grouped_bmm(xg, wc)
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# grouped_matmul: gather -> kernel -> scatter wrapper
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,g,b", [
    (64, 64, 4, 8), (96, 128, 2, 4),
    pytest.param(128, 96, 8, 16, marks=pytest.mark.slow),
    pytest.param(256, 256, 16, 8, marks=pytest.mark.slow),
    pytest.param(80, 48, 4, 3, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_matches_ref(m, n, g, b, dtype):
    key = jax.random.PRNGKey(m + n + g + b)
    x = jax.random.normal(key, (b, m), jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (m, n),
                          jnp.float32).astype(dtype)
    ig = jax.random.normal(jax.random.fold_in(key, 2), (m, g))
    og = jax.random.normal(jax.random.fold_in(key, 3), (g, n))
    plan = make_plan(ig, og)
    got = fops.grouped_matmul(x, w, plan.row_ids, plan.col_ids,
                              plan.row_valid, plan.col_valid, interpret=True)
    want = fref.ref_grouped_matmul(x, w, plan.row_ids, plan.col_ids,
                                   plan.row_valid, plan.col_valid)
    np.testing.assert_allclose(np.asarray(got, jnp.float32),
                               np.asarray(want, jnp.float32), **_tol(dtype))


def test_grouped_matmul_balanced_plan_equals_masked_oracle():
    """When each group has exactly cap rows/cols, the compact path must
    reproduce the paper's masked matmul exactly."""
    m = n = 64
    g = 4
    key = jax.random.PRNGKey(0)
    # permutation-structured IG/OG: exactly m/g rows per group
    row_groups = jnp.tile(jnp.arange(g), m // g)
    col_groups = jnp.tile(jnp.arange(g), n // g)
    ig = jax.nn.one_hot(row_groups, g) * 10.0
    og = jax.nn.one_hot(col_groups, g, axis=0).reshape(g, n) * 10.0
    w = jax.random.normal(key, (m, n))
    x = jax.random.normal(jax.random.fold_in(key, 1), (5, m))
    plan = make_plan(ig, og)
    got = fops.grouped_matmul(x, w, plan.row_ids, plan.col_ids,
                              plan.row_valid, plan.col_valid, interpret=True)
    want = fref.ref_masked_matmul(x, w, row_groups.astype(jnp.int32),
                                  col_groups.astype(jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# grouped_matmul_fused: cached W_c + in-kernel activation gather
# ---------------------------------------------------------------------------

def _fused_pair(m, n, g, b, slack, dtype, seed=None):
    key = jax.random.PRNGKey(seed if seed is not None else m + n + g + b)
    x = jax.random.normal(key, (b, m), jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (m, n),
                          jnp.float32).astype(dtype)
    ig = jax.random.normal(jax.random.fold_in(key, 2), (m, g))
    og = jax.random.normal(jax.random.fold_in(key, 3), (g, n))
    return x, w, make_plan(ig, og, slack)


@pytest.mark.parametrize("m,n,g,b,slack", [
    (64, 64, 4, 8, 1.0), (96, 128, 2, 4, 1.0), (160, 96, 8, 7, 1.3),
    pytest.param(256, 256, 16, 8, 1.0, marks=pytest.mark.slow),
    pytest.param(300, 200, 4, 16, 1.5, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_bitwise_matches_gather_path(m, n, g, b, slack, dtype):
    """The fused consume path (compact ``W_c`` + in-kernel activation
    gather) is *bitwise* equal to the XLA-gather ``grouped_matmul`` —
    same tile sizes, same accumulation order, identical gathered operands
    — so callers can flip paths per call with no parity budget."""
    x, w, plan = _fused_pair(m, n, g, b, slack, dtype)
    wc = fops.compact_weights(w, plan.row_ids, plan.col_ids,
                              plan.row_valid, plan.col_valid)
    got = fops.grouped_matmul_fused(x, wc, plan.row_ids, plan.row_valid,
                                    plan.col_ids, plan.col_valid, n=n,
                                    interpret=True)
    want = fops.grouped_matmul(x, w, plan.row_ids, plan.col_ids,
                               plan.row_valid, plan.col_valid,
                               interpret=True)
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_compact_weights_zeroes_invalid_slots():
    """Invalid (padding) slots of W_c are zero — the property that makes
    the fused path's sink-column gather annihilate padding rows."""
    _, w, plan = _fused_pair(80, 48, 4, 3, 1.5, jnp.float32, seed=9)
    wc = fops.compact_weights(w, plan.row_ids, plan.col_ids,
                              plan.row_valid, plan.col_valid)
    g, cap_m, cap_n = wc.shape
    assert (cap_m, cap_n) == (plan.row_ids.shape[1], plan.col_ids.shape[1])
    invalid = ~(np.asarray(plan.row_valid)[:, :, None]
                & np.asarray(plan.col_valid)[:, None, :])
    assert (np.asarray(wc)[invalid] == 0).all()
    # valid slots are the straight double-gather of W
    rid, cid = np.asarray(plan.row_ids), np.asarray(plan.col_ids)
    want = np.asarray(w)[rid[:, :, None], cid[:, None, :]]
    np.testing.assert_array_equal(np.where(invalid, 0, want), np.asarray(wc))


def test_compact_weights_stacked_layers_fold_through_vmap():
    """Stacked (scanned-decoder) leading dims: compact_weights vmaps and
    each layer's slice is bitwise the per-layer call."""
    layers = []
    for i in range(3):
        x, w, plan = _fused_pair(64, 96, 4, 5, 1.25, jnp.float32, seed=40 + i)
        layers.append((w, plan))
    ws = jnp.stack([w for w, _ in layers])
    stack = lambda f: jnp.stack([f(p) for _, p in layers])  # noqa: E731
    wcs = fops.compact_weights(ws, stack(lambda p: p.row_ids),
                               stack(lambda p: p.col_ids),
                               stack(lambda p: p.row_valid),
                               stack(lambda p: p.col_valid))
    for i, (w, plan) in enumerate(layers):
        one = fops.compact_weights(w, plan.row_ids, plan.col_ids,
                                   plan.row_valid, plan.col_valid)
        np.testing.assert_array_equal(np.asarray(wcs[i]), np.asarray(one))


# ---------------------------------------------------------------------------
# osel_encode kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n", [(8, 8), (128, 512), (300, 200), (1, 64),
                                 (257, 129)])
@pytest.mark.parametrize("g", [2, 4, 16])
def test_encode_mask_kernel_matches_ref(m, n, g):
    key = jax.random.PRNGKey(m * n + g)
    ig_idx = jax.random.randint(key, (m,), 0, g, jnp.int32)
    og_idx = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, g,
                                jnp.int32)
    got = encode_mask(ig_idx, og_idx, interpret=True)
    want = oref.ref_mask_indices(ig_idx, og_idx)
    np.testing.assert_array_equal(np.asarray(got) > 0, np.asarray(want))


def test_osel_mask_wrapper_vs_matmul_baseline():
    """Kernel output == the baseline IS @ OS mask from raw matrices."""
    key = jax.random.PRNGKey(5)
    ig = jax.random.normal(key, (64, 8))
    og = jax.random.normal(jax.random.fold_in(key, 1), (8, 96))
    ig_idx = jnp.argmax(ig, axis=1).astype(jnp.int32)
    og_idx = jnp.argmax(og, axis=0).astype(jnp.int32)
    got = oops.osel_mask(ig_idx, og_idx, interpret=True)
    want = oops.reference_mask(ig, og)
    np.testing.assert_array_equal(np.asarray(got) > 0, np.asarray(want))
