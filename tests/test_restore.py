"""Checkpoint restore of grouped TrainStates: pre-plans manifest migration,
re-encode-on-restore invariance, and bitwise resume parity."""
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (manifest_paths, restore_checkpoint,
                              save_checkpoint)
from repro.core import encoder
from repro.core.flgw import FLGWConfig
from repro.core.schedule import SparsitySchedule
from repro.train import state as state_lib
from repro.train import step as step_lib

_FIX = pathlib.Path(__file__).parent / "fixtures" / "prepr3_ckpt.py"
_spec = importlib.util.spec_from_file_location("prepr3_ckpt", _FIX)
prepr3 = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(prepr3)

FL = FLGWConfig(groups=4, path="grouped")


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


def _batch(cfg, step, b=2, s=16):
    k = jax.random.fold_in(jax.random.PRNGKey(99), step)
    tok = jax.random.randint(k, (b, s), 0, cfg.vocab, jnp.int32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return {"tokens": tok, "targets": tok, "positions": pos}


# ---------------------------------------------------------------------------
# Pre-plans manifest migration
# ---------------------------------------------------------------------------

def test_checked_in_pre_plans_fixture_restores_and_reencodes():
    """The checked-in pre-PR-3-style grouped checkpoint (manifest without
    plans leaves) restores through ``restore_state`` and comes back with
    plans freshly encoded from the restored params."""
    cfg = prepr3.tiny_cfg()
    target = prepr3.init_fixture_state()
    restored, step = state_lib.restore_state(prepr3.FIXTURE_DIR, target, cfg)
    assert step == prepr3.FIXTURE_STEP
    assert isinstance(restored.plans, encoder.PlanState)
    fresh = encoder.encode_plans(restored.params, FL)
    assert _tree_equal(restored.plans, fresh)
    # and the fixture really is pre-plans-shaped
    assert not any(".plans" in p
                   for p in manifest_paths(prepr3.FIXTURE_DIR))


def test_strict_restore_of_pre_plans_manifest_raises_with_guidance():
    target = prepr3.init_fixture_state()
    with pytest.raises(KeyError, match="restore_state"):
        restore_checkpoint(prepr3.FIXTURE_DIR, target)


def test_non_strict_restore_keeps_unrecorded_target_leaves():
    target = prepr3.init_fixture_state()
    # poison a recorded leaf to prove it is loaded, not passed through
    target = target._replace(step=jnp.full((), 42, jnp.int32))
    got, step = restore_checkpoint(prepr3.FIXTURE_DIR, target, strict=False)
    assert step == prepr3.FIXTURE_STEP
    # plans leaves aren't in the manifest: target's own plans pass through
    assert _tree_equal(got.plans, target.plans)
    # recorded leaves come from the checkpoint, not the target
    assert int(got.step) == prepr3.FIXTURE_STEP


def test_pre_plans_roundtrip_migrates(tmp_path):
    """Saving ``state._replace(plans=())`` reproduces the pre-PR-3 manifest
    shape; restore_state migrates it."""
    cfg = prepr3.tiny_cfg()
    state = prepr3.init_fixture_state()
    save_checkpoint(tmp_path, 4, state._replace(plans=()))
    restored, step = state_lib.restore_state(tmp_path, state, cfg)
    assert step == 4
    assert _tree_equal(restored.params, state.params)
    assert _tree_equal(restored.plans,
                       encoder.encode_plans(restored.params, FL))


# ---------------------------------------------------------------------------
# Re-encode on restore (stale-plans bug)
# ---------------------------------------------------------------------------

def test_restore_reencodes_stale_checkpointed_plans(tmp_path):
    """A plans-era checkpoint holds whatever plans were current at save
    time; restore must not trust them. Poisoned plans in the checkpoint
    come back as a fresh encode of the restored params."""
    cfg = prepr3.tiny_cfg()
    state = prepr3.init_fixture_state()
    poisoned = state._replace(plans=encoder.PlanState(
        jax.tree.map(jnp.zeros_like, state.plans.plans),
        jnp.zeros((), jnp.uint32)))
    save_checkpoint(tmp_path, 6, poisoned)
    restored, _ = state_lib.restore_state(tmp_path, state, cfg)
    assert _tree_equal(restored.plans,
                       encoder.encode_plans(restored.params, FL))


@pytest.mark.parametrize("refresh", ["on_change", "period"])
def test_post_restore_step_bitwise_matches_uninterrupted(tmp_path, refresh):
    """The acceptance bar: checkpoint at step k, restore, step once — the
    resulting state is bitwise-identical to the run that never stopped,
    for change-driven and periodic refresh alike (restore re-encodes, and
    the layout-rank signature guarantees carried plans match a fresh
    encode bitwise)."""
    cfg = prepr3.tiny_cfg()
    sched = SparsitySchedule(groups=4, refresh_every=2, refresh=refresh)
    step_fn = jax.jit(step_lib.make_train_step(
        cfg, optimizer="rmsprop", lr=1e-2, schedule=sched))
    state = prepr3.init_fixture_state()
    for t in range(2):
        state, _ = step_fn(state, _batch(cfg, t))
    save_checkpoint(tmp_path, 2, state)

    cont, _ = step_fn(state, _batch(cfg, 2))           # never interrupted

    target = prepr3.init_fixture_state()               # fresh process
    restored, start = state_lib.restore_state(tmp_path, target, cfg)
    assert start == 2
    resumed, _ = step_fn(restored, _batch(cfg, 2))

    assert _tree_equal(cont.params, resumed.params)
    assert _tree_equal(cont.opt, resumed.opt)
    assert _tree_equal(cont.plans, resumed.plans)
    assert int(cont.step) == int(resumed.step) == 3
