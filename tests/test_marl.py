"""MARL system tests: env invariants, IC3Net, short FLGW training runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.marl import env as env_mod
from repro.marl import ic3net
from repro.marl import train as train_mod


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), a=st.integers(1, 6),
       size=st.integers(3, 8))
def test_env_positions_stay_in_bounds(seed, a, size):
    cfg = env_mod.EnvConfig(n_agents=a, size=size, max_steps=8)
    key = jax.random.PRNGKey(seed)
    state = env_mod.reset(key, cfg)
    for i in range(8):
        k = jax.random.fold_in(key, i)
        actions = jax.random.randint(k, (a,), 0, env_mod.N_ACTIONS)
        state, rew, done = env_mod.step(state, actions, cfg)
        assert (np.asarray(state.pos) >= 0).all()
        assert (np.asarray(state.pos) < size).all()
        assert rew.shape == (a,)


def test_env_arrived_agents_freeze_and_success():
    cfg = env_mod.EnvConfig(n_agents=2, size=3, max_steps=10)
    state = env_mod.EnvState(
        pos=jnp.array([[1, 1], [0, 0]], jnp.int32),
        prey=jnp.array([1, 1], jnp.int32),
        arrived=jnp.zeros((2,), bool), t=jnp.zeros((), jnp.int32))
    state, rew, done = env_mod.step(state, jnp.array([0, 0]), cfg)
    assert bool(state.arrived[0]) and not bool(state.arrived[1])
    assert float(rew[0]) > 0 > float(rew[1])
    # agent 1 walks to the prey
    state, _, _ = env_mod.step(state, jnp.array([0, 2]), cfg)  # down
    state, _, done = env_mod.step(state, jnp.array([0, 4]), cfg)  # right
    assert bool(env_mod.success(state))
    assert bool(done)


def test_env_observation_shape_and_prey_visibility():
    cfg = env_mod.EnvConfig(n_agents=3, size=5, vision=1)
    state = env_mod.reset(jax.random.PRNGKey(0), cfg)
    obs = env_mod.observe(state, cfg)
    assert obs.shape == (3, env_mod.obs_dim(cfg))
    off = np.abs(np.asarray(state.prey)[None] - np.asarray(state.pos))
    seen = (off <= cfg.vision).all(axis=1)
    np.testing.assert_array_equal(np.asarray(obs[:, -1]) > 0.5, seen)


@pytest.mark.parametrize("groups,path", [(1, "masked"), (4, "masked"),
                                         (4, "grouped")])
def test_ic3net_short_training_runs(groups, path):
    cfg = ic3net.IC3NetConfig(hidden=32, flgw_groups=groups, flgw_path=path)
    ecfg = env_mod.EnvConfig(n_agents=3, size=4, max_steps=8)
    tcfg = train_mod.TrainConfig(batch=4)
    params, hist = train_mod.train(cfg, ecfg, tcfg, iterations=3)
    assert len(hist) == 3
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_ic3net_gate_controls_communication():
    """Gate=0 must zero the communication input (learning when to talk)."""
    cfg = ic3net.IC3NetConfig(hidden=16, n_agents=3, n_actions=5, obs_dim=7)
    params, _ = ic3net.init(jax.random.PRNGKey(0), cfg)
    obs = jnp.ones((3, 7))
    hc, _ = ic3net.initial_state(cfg)
    hc = (jnp.ones_like(hc[0]) * 0.3, hc[1])  # nonzero hidden so comm != 0
    lg_on, _, _, _ = ic3net.policy_step(params, cfg, obs, hc,
                                        jnp.ones((3,)))
    lg_off, _, _, _ = ic3net.policy_step(params, cfg, obs, hc,
                                         jnp.zeros((3,)))
    assert not np.allclose(np.asarray(lg_on), np.asarray(lg_off))


def test_ic3net_learns_more_than_random_on_tiny_task():
    """Sanity: success rate after training ≥ before (tiny budget, loose)."""
    cfg = ic3net.IC3NetConfig(hidden=32)
    ecfg = env_mod.EnvConfig(n_agents=2, size=3, vision=2, max_steps=6)
    tcfg = train_mod.TrainConfig(batch=16)
    params, hist = train_mod.train(cfg, ecfg, tcfg, iterations=40, seed=1)
    first = np.mean([h["success"] for h in hist[:5]])
    last = np.mean([h["success"] for h in hist[-5:]])
    assert last >= first - 0.05


def test_scan_loop_matches_host_loop_on_predator_prey():
    """The on-device lax.scan loop must reproduce the seed host loop:
    same seed + same config ⇒ same success/loss trajectory and params."""
    cfg = ic3net.IC3NetConfig(hidden=16)
    ecfg = env_mod.EnvConfig(n_agents=2, size=3, max_steps=6)
    tcfg = train_mod.TrainConfig(batch=4)
    p_host, h_host = train_mod.train(cfg, ecfg, tcfg, iterations=6, seed=0,
                                     host_loop=True)
    p_scan, h_scan = train_mod.train(cfg, ecfg, tcfg, iterations=6, seed=0,
                                     log_every=2)
    np.testing.assert_allclose([h["success"] for h in h_host],
                               [h["success"] for h in h_scan], atol=1e-6)
    np.testing.assert_allclose([h["loss"] for h in h_host],
                               [h["loss"] for h in h_scan], rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p_host), jax.tree.leaves(p_scan)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("env_name",
                         ["predator_prey", "traffic_junction", "spread"])
def test_engine_trains_every_registered_env(env_name):
    from repro.marl import envs
    env, ecfg = envs.make(env_name)
    cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=4)
    tcfg = train_mod.TrainConfig(batch=2)
    _, hist = train_mod.train(cfg, ecfg, tcfg, iterations=2, seed=0,
                              env=env_name)
    assert len(hist) == 2
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert all(0.0 <= h["success"] <= 1.0 for h in hist)


def test_sparsity_schedule_warmup_runs_dense_then_sparse():
    """G-ramp: the warmup iterations run the dense path inside the scan,
    then the FLGW mask switches on — the loop must stay finite across the
    boundary and train the grouping matrices afterwards."""
    from repro.core.schedule import SparsitySchedule
    cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=4)
    ecfg = env_mod.EnvConfig(n_agents=2, size=3, max_steps=6)
    tcfg = train_mod.TrainConfig(batch=4)
    sched = SparsitySchedule(groups=4, warmup_steps=3)
    params, hist = train_mod.train(cfg, ecfg, tcfg, iterations=6, seed=0,
                                   schedule=sched)
    assert len(hist) == 6
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert sched.groups_at(0) == 1 and sched.groups_at(3) == 4
    # grouping matrices exist and received updates after warmup
    assert "ig" in params["enc"]
    # the sparsity metric must describe the compute that actually ran:
    # 0 while the dense warmup branch executes, ~1-1/G afterwards
    assert all(h["mask_sparsity"] == 0.0 for h in hist[:3])
    assert all(h["mask_sparsity"] > 0.5 for h in hist[3:])


def test_masked_vs_grouped_training_trajectories_close():
    """The compact grouped path inside the scan must track the masked
    (full-FLOPs numerical oracle) training run: same seed, same config ⇒
    near-identical loss/success trajectories (small drift allowed — the
    capacity-balanced layout spills a few rows, and dIG/dOG use the
    sparse-restricted STE)."""
    ecfg = env_mod.EnvConfig(n_agents=2, size=3, max_steps=6)
    tcfg = train_mod.TrainConfig(batch=8)
    hists = {}
    for path in ("masked", "grouped"):
        cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=2, flgw_path=path)
        _, hists[path] = train_mod.train(cfg, ecfg, tcfg, iterations=8,
                                         seed=0)
    lm = np.array([h["loss"] for h in hists["masked"]])
    lg = np.array([h["loss"] for h in hists["grouped"]])
    np.testing.assert_allclose(lg, lm, rtol=0.5, atol=0.5)
    sm = np.array([h["success"] for h in hists["masked"]])
    sg = np.array([h["success"] for h in hists["grouped"]])
    assert np.abs(sg - sm).max() <= 0.25


def test_grouped_scan_loop_matches_host_loop():
    """Plan-cache parity: the scan carry's refreshed plans must reproduce
    the host loop's explicit refresh — same params and trajectories."""
    from repro.core.schedule import SparsitySchedule
    cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=4, flgw_path="grouped")
    ecfg = env_mod.EnvConfig(n_agents=2, size=3, max_steps=6)
    tcfg = train_mod.TrainConfig(batch=4)
    sched = SparsitySchedule(groups=4, refresh_every=2)
    p_host, h_host = train_mod.train(cfg, ecfg, tcfg, iterations=4, seed=0,
                                     schedule=sched, host_loop=True)
    p_scan, h_scan = train_mod.train(cfg, ecfg, tcfg, iterations=4, seed=0,
                                     schedule=sched, log_every=2)
    np.testing.assert_allclose([h["loss"] for h in h_host],
                               [h["loss"] for h in h_scan], rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p_host), jax.tree.leaves(p_scan)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_plan_refresh_reuses_stale_plans_until_boundary():
    """refresh_every=k: iterations with it % k != 0 must pass the carried
    (stale) plans through bit-identically; it % k == 0 must re-encode from
    the current grouping matrices."""
    from repro.core.schedule import SparsitySchedule
    cfg = ic3net.IC3NetConfig(hidden=16, obs_dim=7, flgw_groups=4,
                              flgw_path="grouped")
    params, _ = ic3net.init(jax.random.PRNGKey(0), cfg)
    fresh = ic3net.encode_plans(params, cfg)
    # a deliberately wrong ("stale") cache: plans of different params
    other, _ = ic3net.init(jax.random.PRNGKey(1), cfg)
    stale = ic3net.encode_plans(other, cfg)
    sched = SparsitySchedule(groups=4, refresh_every=3)
    for it in range(7):
        got = jax.jit(train_mod.maybe_refresh_plans,
                      static_argnames=("cfg", "schedule"))(
            params, stale, it, cfg=cfg, schedule=sched)
        want = fresh if it % 3 == 0 else stale
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grouped_scan_on_change_matches_host_loop():
    """Change-driven refresh inside the scan carry: the hash compare +
    conditional re-encode must mirror the host loop exactly (same jitted
    maybe_refresh), so trajectories and params agree bit-for-bit-ish."""
    from repro.core.schedule import SparsitySchedule
    cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=4, flgw_path="grouped")
    ecfg = env_mod.EnvConfig(n_agents=2, size=3, max_steps=6)
    tcfg = train_mod.TrainConfig(batch=4, lr=0.05)   # lr high: masks churn
    sched = SparsitySchedule(groups=4, refresh="on_change")
    p_host, h_host = train_mod.train(cfg, ecfg, tcfg, iterations=5, seed=0,
                                     schedule=sched, host_loop=True)
    p_scan, h_scan = train_mod.train(cfg, ecfg, tcfg, iterations=5, seed=0,
                                     schedule=sched, log_every=2)
    np.testing.assert_allclose([h["loss"] for h in h_host],
                               [h["loss"] for h in h_scan], rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p_host), jax.tree.leaves(p_scan)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_env_shim_still_resolves_with_deprecation_warning():
    """repro.marl.env stays importable (seed API) but warns, pointing at
    the envs registry."""
    import importlib
    import warnings as w

    from repro.marl import env as shim
    with w.catch_warnings(record=True) as caught:
        w.simplefilter("always")
        shim = importlib.reload(shim)
    assert any(issubclass(c.category, DeprecationWarning) for c in caught)
    assert any("repro.marl.envs" in str(c.message) for c in caught)
    from repro.marl.envs import predator_prey
    assert shim.reset is predator_prey.reset
    assert shim.EnvConfig is predator_prey.EnvConfig


def test_grouped_stale_plans_actually_change_training():
    """Amortization must be real: with a learning rate high enough to move
    the grouping matrices, refresh_every=4 must diverge from refresh_every=1
    (if plans were silently re-encoded per projection the two would match)."""
    from repro.core.schedule import SparsitySchedule
    cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=4, flgw_path="grouped")
    ecfg = env_mod.EnvConfig(n_agents=2, size=3, max_steps=6)
    tcfg = train_mod.TrainConfig(batch=4, lr=0.05)
    losses = {}
    for k in (1, 4):
        sched = SparsitySchedule(groups=4, refresh_every=k)
        _, hist = train_mod.train(cfg, ecfg, tcfg, iterations=6, seed=0,
                                  schedule=sched)
        losses[k] = np.array([h["loss"] for h in hist])
        assert np.isfinite(losses[k]).all()
    assert not np.allclose(losses[1], losses[4])


def test_encode_happens_once_per_refresh_not_per_projection():
    """Regression guard for the OSEL amortization: tracing one training
    chunk must hit make_plan exactly once per FLGW layer (inside the
    refresh cond), independent of iterations/batch/rollout length — NOT
    once per projection call (the plan=None fallback)."""
    from repro.analysis.contracts import trace_counter
    from repro.core import grouped
    from repro.core.schedule import SparsitySchedule
    cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=4, flgw_path="grouped")
    ecfg = env_mod.EnvConfig(n_agents=2, size=3, max_steps=6)
    tcfg = train_mod.TrainConfig(batch=3)
    from repro.marl import envs
    e = envs.get("predator_prey")
    cfg2, key, params, opt_state = train_mod._init(cfg, ecfg, e, seed=0)
    plans = ic3net.encode_plans(params, cfg2)
    n_flgw_layers = len(plans.plans)
    assert n_flgw_layers == 5    # enc, lstm_x, lstm_h, comm, policy
    with trace_counter(grouped, "make_plan") as calls:
        # eager _scan_chunk: lax.scan traces the body exactly once
        train_mod._scan_chunk(params, opt_state, key, plans,
                              jnp.zeros((), jnp.int32), 4, cfg2, ecfg,
                              tcfg, e,
                              SparsitySchedule(groups=4, refresh_every=2))
    assert calls.count == n_flgw_layers, calls.count


def test_history_carries_throughput_and_sparsity_metrics():
    """Per-iteration metrics from inside the scan: realised mask sparsity
    plus host-derived steps/s and estimated sparse GFLOPS."""
    cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=4)
    ecfg = env_mod.EnvConfig(n_agents=2, size=3, max_steps=6)
    _, hist = train_mod.train(cfg, ecfg, train_mod.TrainConfig(batch=2),
                              iterations=3, seed=0)
    for h in hist:
        assert 0.0 <= h["mask_sparsity"] < 1.0
        assert h["steps_per_s"] > 0
        assert h["env_steps_per_s"] == pytest.approx(
            h["steps_per_s"] * 2 * 6)
        assert h["sparse_gflops"] > 0
    # G=4 random grouping realises roughly 1 - 1/G sparsity
    assert hist[0]["mask_sparsity"] == pytest.approx(0.75, abs=0.15)


def _run_forced_devices(code: str, n_devices: int):
    """Run ``code`` in a subprocess with ``n_devices`` forced CPU devices
    (the flag must be set before JAX initializes — hence a subprocess)."""
    import os
    import pathlib
    import subprocess
    import sys

    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(
        os.environ,
        XLA_FLAGS=f"--xla_force_host_platform_device_count={n_devices}",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=f"{root / 'src'}"
                   f"{os.pathsep + os.environ['PYTHONPATH'] if os.environ.get('PYTHONPATH') else ''}")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         cwd=root, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, out.stderr


def test_deprecated_parallel_alias_runs_on_forced_devices():
    """tcfg.parallel (the retired pmap switch) must keep working: it now
    routes to a 1-D env-only mesh over the local devices, with a
    DeprecationWarning."""
    _run_forced_devices(
        "import warnings\n"
        "import jax, numpy as np\n"
        "assert jax.local_device_count() == 2\n"
        "from repro.marl import ic3net, train as T, envs\n"
        "cfg = ic3net.IC3NetConfig(hidden=16)\n"
        "env, ecfg = envs.make('predator_prey', n_agents=2, size=3,"
        " max_steps=6)\n"
        "tcfg = T.TrainConfig(batch=4, parallel=True)\n"
        "with warnings.catch_warnings(record=True) as w:\n"
        "    warnings.simplefilter('always')\n"
        "    _, hist = T.train(cfg, ecfg, tcfg, iterations=4, seed=0)\n"
        "assert any(issubclass(c.category, DeprecationWarning) for c in w)\n"
        "assert len(hist) == 4\n"
        "assert all(np.isfinite(h['loss']) for h in hist), hist\n",
        n_devices=2)


def _train_all_paths(cfg, ecfg, iterations, schedule=None, batch=4,
                     log_every=0):
    """(host, scan, mesh(1,1), parallel-alias) runs of one config."""
    import warnings as w
    runs = {}
    for name, tcfg, host in (
            ("host", train_mod.TrainConfig(batch=batch), True),
            ("scan", train_mod.TrainConfig(batch=batch), False),
            ("mesh", train_mod.TrainConfig(batch=batch, mesh=(1, 1)), False),
            ("alias", train_mod.TrainConfig(batch=batch, parallel=True),
             False)):
        with w.catch_warnings():
            w.simplefilter("ignore", DeprecationWarning)
            runs[name] = train_mod.train(
                cfg, ecfg, tcfg, iterations=iterations, seed=0,
                schedule=schedule, host_loop=host, log_every=log_every)
    return runs


def _assert_params_equal(pa, pb, bitwise=True):
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        if bitwise:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)


def test_mesh_path_three_way_parity_dense():
    """Single device: the mesh path must train BITWISE-identically to the
    plain scan and the deprecated parallel alias (all three trace the same
    _scan_chunk), and match the host loop — the scale-out substrate cannot
    change the numbers it scales."""
    cfg = ic3net.IC3NetConfig(hidden=16)
    ecfg = env_mod.EnvConfig(n_agents=2, size=3, max_steps=6)
    runs = _train_all_paths(cfg, ecfg, iterations=5)
    _assert_params_equal(runs["scan"][0], runs["mesh"][0])
    _assert_params_equal(runs["mesh"][0], runs["alias"][0])
    _assert_params_equal(runs["host"][0], runs["mesh"][0], bitwise=False)
    np.testing.assert_allclose([h["loss"] for h in runs["host"][1]],
                               [h["loss"] for h in runs["mesh"][1]],
                               rtol=1e-4)


def test_mesh_path_three_way_parity_grouped_refresh_in_window():
    """Grouped path with a refresh_every boundary landing *inside* a scan
    window (it=3 of a 5-iteration window): the PlanState carry must
    refresh identically on the host loop, the scan and the mesh path."""
    from repro.core.schedule import SparsitySchedule
    cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=4, flgw_path="grouped")
    ecfg = env_mod.EnvConfig(n_agents=2, size=3, max_steps=6)
    sched = SparsitySchedule(groups=4, refresh_every=3)
    runs = _train_all_paths(cfg, ecfg, iterations=5, schedule=sched,
                            log_every=5)
    _assert_params_equal(runs["scan"][0], runs["mesh"][0])
    _assert_params_equal(runs["mesh"][0], runs["alias"][0])
    _assert_params_equal(runs["host"][0], runs["mesh"][0], bitwise=False)
    np.testing.assert_allclose([h["loss"] for h in runs["host"][1]],
                               [h["loss"] for h in runs["mesh"][1]],
                               rtol=1e-4)


def test_mesh_axes_actually_partition_on_forced_devices():
    """Forced 4-device host, (2 env x 2 agent) mesh: the env and agent
    constraints must produce PARTITIONED shardings (no silent full
    replication — the failure mode where a logical rule or divisibility
    drop silently replicates everything), the lowered train chunk must
    carry those shardings, and a grouped mesh run with a refresh inside
    the window must train finite."""
    _run_forced_devices(
        "import jax, jax.numpy as jnp, numpy as np\n"
        "assert jax.local_device_count() == 4\n"
        "from repro.core.schedule import SparsitySchedule\n"
        "from repro.launch.mesh import make_marl_mesh\n"
        "from repro.marl import ic3net, train as T, envs\n"
        "from repro.sharding import partition\n"
        "mesh = make_marl_mesh(env=2, agent=2)\n"
        "with mesh, partition.use_constraints(mesh):\n"
        "    ke = jax.jit(lambda x: partition.constrain(x, ('env', None)))("
        "jnp.zeros((4, 2)))\n"
        "    ag = jax.jit(lambda x: partition.constrain(x, ('agent', None)))("
        "jnp.zeros((4, 8)))\n"
        "assert not ke.sharding.is_fully_replicated, ke.sharding\n"
        "assert not ag.sharding.is_fully_replicated, ag.sharding\n"
        "assert 'env' in str(ke.sharding.spec)\n"
        "assert 'agent' in str(ag.sharding.spec)\n"
        "cfg = ic3net.IC3NetConfig(hidden=16, flgw_groups=4,"
        " flgw_path='grouped')\n"
        "env, ecfg = envs.make('predator_prey', n_agents=4, size=3,"
        " max_steps=6)\n"
        "sched = SparsitySchedule(groups=4, refresh_every=3)\n"
        "cfg2, key, params, opt = T._init(cfg, ecfg, env, seed=0)\n"
        "plans = T._encode_plans(params, cfg2)\n"
        "tcfg = T.TrainConfig(batch=4, mesh=(2, 2))\n"
        "chunk = T.make_mesh_chunk(mesh)\n"
        "with T._mesh_contexts(mesh):\n"
        "    lowered = chunk.lower(params, opt, key, plans,\n"
        "        jnp.zeros((), jnp.int32), 5, cfg2, ecfg, tcfg, env, sched)\n"
        "txt = lowered.as_text()\n"
        "assert 'devices=[' in txt, 'no partitioned sharding in the chunk'\n"
        "_, hist = T.train(cfg, ecfg, tcfg, iterations=5, seed=0,"
        " schedule=sched, log_every=5)\n"
        "assert len(hist) == 5\n"
        "assert all(np.isfinite(h['loss']) for h in hist), hist\n",
        n_devices=4)
