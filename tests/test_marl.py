"""MARL system tests: env invariants, IC3Net, short FLGW training runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.marl import env as env_mod
from repro.marl import ic3net
from repro.marl import train as train_mod


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), a=st.integers(1, 6),
       size=st.integers(3, 8))
def test_env_positions_stay_in_bounds(seed, a, size):
    cfg = env_mod.EnvConfig(n_agents=a, size=size, max_steps=8)
    key = jax.random.PRNGKey(seed)
    state = env_mod.reset(key, cfg)
    for i in range(8):
        k = jax.random.fold_in(key, i)
        actions = jax.random.randint(k, (a,), 0, env_mod.N_ACTIONS)
        state, rew, done = env_mod.step(state, actions, cfg)
        assert (np.asarray(state.pos) >= 0).all()
        assert (np.asarray(state.pos) < size).all()
        assert rew.shape == (a,)


def test_env_arrived_agents_freeze_and_success():
    cfg = env_mod.EnvConfig(n_agents=2, size=3, max_steps=10)
    state = env_mod.EnvState(
        pos=jnp.array([[1, 1], [0, 0]], jnp.int32),
        prey=jnp.array([1, 1], jnp.int32),
        arrived=jnp.zeros((2,), bool), t=jnp.zeros((), jnp.int32))
    state, rew, done = env_mod.step(state, jnp.array([0, 0]), cfg)
    assert bool(state.arrived[0]) and not bool(state.arrived[1])
    assert float(rew[0]) > 0 > float(rew[1])
    # agent 1 walks to the prey
    state, _, _ = env_mod.step(state, jnp.array([0, 2]), cfg)  # down
    state, _, done = env_mod.step(state, jnp.array([0, 4]), cfg)  # right
    assert bool(env_mod.success(state))
    assert bool(done)


def test_env_observation_shape_and_prey_visibility():
    cfg = env_mod.EnvConfig(n_agents=3, size=5, vision=1)
    state = env_mod.reset(jax.random.PRNGKey(0), cfg)
    obs = env_mod.observe(state, cfg)
    assert obs.shape == (3, env_mod.obs_dim(cfg))
    off = np.abs(np.asarray(state.prey)[None] - np.asarray(state.pos))
    seen = (off <= cfg.vision).all(axis=1)
    np.testing.assert_array_equal(np.asarray(obs[:, -1]) > 0.5, seen)


@pytest.mark.parametrize("groups,path", [(1, "masked"), (4, "masked"),
                                         (4, "grouped")])
def test_ic3net_short_training_runs(groups, path):
    cfg = ic3net.IC3NetConfig(hidden=32, flgw_groups=groups, flgw_path=path)
    ecfg = env_mod.EnvConfig(n_agents=3, size=4, max_steps=8)
    tcfg = train_mod.TrainConfig(batch=4)
    params, hist = train_mod.train(cfg, ecfg, tcfg, iterations=3)
    assert len(hist) == 3
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_ic3net_gate_controls_communication():
    """Gate=0 must zero the communication input (learning when to talk)."""
    cfg = ic3net.IC3NetConfig(hidden=16, n_agents=3, n_actions=5, obs_dim=7)
    params, _ = ic3net.init(jax.random.PRNGKey(0), cfg)
    obs = jnp.ones((3, 7))
    hc, _ = ic3net.initial_state(cfg)
    hc = (jnp.ones_like(hc[0]) * 0.3, hc[1])  # nonzero hidden so comm != 0
    lg_on, _, _, _ = ic3net.policy_step(params, cfg, obs, hc,
                                        jnp.ones((3,)))
    lg_off, _, _, _ = ic3net.policy_step(params, cfg, obs, hc,
                                         jnp.zeros((3,)))
    assert not np.allclose(np.asarray(lg_on), np.asarray(lg_off))


def test_ic3net_learns_more_than_random_on_tiny_task():
    """Sanity: success rate after training ≥ before (tiny budget, loose)."""
    cfg = ic3net.IC3NetConfig(hidden=32)
    ecfg = env_mod.EnvConfig(n_agents=2, size=3, vision=2, max_steps=6)
    tcfg = train_mod.TrainConfig(batch=16)
    params, hist = train_mod.train(cfg, ecfg, tcfg, iterations=40, seed=1)
    first = np.mean([h["success"] for h in hist[:5]])
    last = np.mean([h["success"] for h in hist[-5:]])
    assert last >= first - 0.05
