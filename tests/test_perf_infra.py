"""Perf infrastructure: grad compression, schedules, roofline parsers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.schedule import SparsitySchedule
from repro.launch import roofline
from repro.optim.compression import (CompressionState, compression_init,
                                     topk_compress, topk_decompress)


# ---------------------------------------------------------------------------
# Top-k gradient compression
# ---------------------------------------------------------------------------

def test_topk_roundtrip_keeps_largest():
    g = jnp.array([0.1, -5.0, 0.01, 3.0, -0.2, 0.0])
    vals, idx, k = topk_compress(g, ratio=0.34)     # k = 2
    assert k == 2
    dense = topk_decompress(vals, idx, g.shape)
    np.testing.assert_allclose(np.asarray(dense),
                               [0, -5.0, 0, 3.0, 0, 0], atol=1e-6)


def test_error_feedback_accumulates_residual():
    """What is not sent this step must be sent eventually (EF property):
    over T rounds the average transmitted gradient converges to the true
    gradient with error bounded by residual/T."""
    grads = {"w": jnp.array([1.0, 0.5, 0.25, 0.125])}
    state = compression_init(grads)
    rounds = 64
    total_sent = jnp.zeros(4)
    for _ in range(rounds):
        g32 = grads["w"] + state.error["w"]
        vals, idx, _ = topk_compress(g32, 0.25)   # k=1 per round
        sent = topk_decompress(vals, idx, (4,))
        state = CompressionState(error={"w": g32 - sent})
        total_sent = total_sent + sent
    avg = np.asarray(total_sent / rounds)
    # residual is bounded, so |avg - g| <= max|residual| / rounds
    bound = float(np.abs(np.asarray(state.error["w"])).max()) / rounds + 0.05
    np.testing.assert_allclose(avg, np.asarray(grads["w"]), atol=bound + 0.1)


# ---------------------------------------------------------------------------
# Sparsity schedule
# ---------------------------------------------------------------------------

def test_schedule_warmup_and_refresh():
    s = SparsitySchedule(groups=8, refresh_every=4, warmup_steps=10)
    assert s.groups_at(0) == 1 and s.groups_at(9) == 1
    assert s.groups_at(10) == 8
    assert s.refresh_at(0) and s.refresh_at(8) and not s.refresh_at(3)
    assert s.avg_sparsity == pytest.approx(1 - 1 / 8)


# ---------------------------------------------------------------------------
# Roofline HLO parsers
# ---------------------------------------------------------------------------

_HLO = """
HloModule test
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = bf16[64,64]{1,0} parameter(1)
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %p0), replica_groups={{0,1,2,3}}
  %ag = f32[512,256]{1,0} all-gather(f32[128,256]{1,0} %ar), replica_groups=[2,4]<=[8]
  %d = f32[128,64]{1,0} dot(f32[128,256]{1,0} %ar, f32[256,64]{1,0} %x)
  %t = f32[128,64]{1,0} tanh(f32[128,64]{1,0} %d)
  ROOT %r = f32[128]{0} reduce(f32[128,64]{1,0} %t, f32[] %c)
}
"""


def test_collective_bytes_parser():
    out = roofline.collective_bytes_from_hlo(_HLO)
    ar = 128 * 256 * 4
    ag = 512 * 256 * 4
    assert out["all-reduce"] == pytest.approx(ar * 2 * 3 / 4)
    assert out["all-gather"] == pytest.approx(ag * 3 / 4)
    assert out["count"] == 2


def test_fused_bytes_counts_dots_reduces_params_only():
    got = roofline.fused_bytes_from_hlo(_HLO)
    params = 128 * 256 * 4 + 64 * 64 * 2
    dot = (128 * 64 + 128 * 256 + 256 * 64) * 4
    red = (128 + 128 * 64) * 4
    # tanh (elementwise) must NOT be counted
    assert got == pytest.approx(params + dot + red, rel=0.01)


def test_roofline_terms_dominant_and_fraction():
    t = roofline.roofline_terms(
        flops_per_chip=1.97e14, bytes_per_chip=819e9 / 2,
        collective_bytes_per_chip=5e9, model_flops_total=1.97e14 * 128,
        chips=256, fused_bytes_per_chip=819e9 / 4)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_fused_s"] == pytest.approx(0.25)
    assert t["dominant"] == "compute_s"
    assert t["roofline_fraction"] == pytest.approx(0.5)


def test_flash_cost_scales_with_window():
    from repro.configs import registry
    cfg = registry.get_config("gemma2_2b")
    full = roofline.flash_attention_cost(cfg, batch=8, seq=8192,
                                         kind="train")
    cfg_small_w = cfg.with_updates(pattern=tuple(
        s.__class__(**{**s.__dict__, "window": 512}) for s in cfg.pattern))
    small = roofline.flash_attention_cost(cfg_small_w, batch=8, seq=8192,
                                          kind="train")
    assert small["flops"] < full["flops"]


def test_model_flops_moe_counts_active_only():
    from repro.configs import registry
    mix = registry.get_config("mixtral_8x22b")
    total = roofline.model_flops(mix, 1000, kind="train")
    from repro.models.config import active_param_count, param_count
    assert active_param_count(mix) < param_count(mix) / 2
    assert total == pytest.approx(6 * active_param_count(mix) * 1000)
