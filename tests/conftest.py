import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def _isolate_size_fallback_latch():
    """Snapshot/restore the plan-encode oversize-warning latch per test.

    The latch is once-per-process state; without this, whichever test
    touched it last decided whether any later test's oversize encode
    could warn (order-dependent flakes across files).
    """
    from repro.kernels.plan_encode import ops as pe_ops

    prev = pe_ops.size_fallback_warned()
    yield
    pe_ops.reset_size_fallback_warning(prev)
