import jax

jax.config.update("jax_enable_x64", False)
