"""Continuous-batching engine invariants (``repro.serving.scheduler``).

The load-bearing guarantees:

* **slot isolation** — neighbours joining and retiring mid-flight leave
  a request's generated tokens bitwise identical to running it alone
  (the per-slot cache rows really are independent streams);
* **lockstep parity** — the per-slot engine under ``admission=
  "lockstep"`` reproduces the classic scalar-``pos`` serve loop token
  for token (the baseline in fig14 is the old behavior, re-expressed);
* **scheduling wins are structural** — on a ragged open-loop stream,
  continuous admission needs strictly fewer compute steps than lockstep
  at equal capacity (what the tokens/s gap in BENCH_serving.json rests
  on);
* **plan economy** — a whole multi-request run costs one
  ``make_plan``-per-layer encode (admission certifies through the
  process plan cache, it does not re-encode);
* **slot recycling** — ``transformer.reset_slots`` rewinds exactly the
  masked rows (pos to 0, SSM state/conv to 0) and leaves other rows
  bitwise untouched; stale KV needs no scrub because a rewound ``pos``
  masks the whole ring.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.contracts import trace_counter
from repro.configs import registry
from repro.core import encoder, grouped
from repro.models import transformer
from repro.serving import (Engine, Request, ServeSession, plan_cache,
                           synthetic_requests)
from repro.serving.stream import max_seq_for


def _tiny_cfg(**kw):
    from repro.models.config import ModelConfig
    base = dict(name="sched_test", family="dense", n_layers=2, d_model=64,
                n_heads=2, n_kv_heads=2, head_dim=32, d_ff=128, vocab=256,
                flgw_groups=4, flgw_path="grouped",
                flgw_targets=("mlp", "attn"), dtype=jnp.float32, remat=False)
    base.update(kw)
    return ModelConfig(**base)


def _prompt(seed, n, vocab=256):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,),
                                         0, vocab, jnp.int32))


@pytest.fixture(autouse=True)
def _fresh_plan_cache():
    plan_cache.clear()
    yield
    plan_cache.clear()


@pytest.fixture(scope="module")
def session():
    cfg = _tiny_cfg()
    params, _ = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    return ServeSession(cfg, params, plan_policy="certify")


# -- slot isolation ----------------------------------------------------------

def test_join_and_retire_leave_neighbours_bitwise_unchanged(session):
    """Request A alone vs A with B retiring and C joining mid-flight:
    A's token stream must not move by a single bit."""
    a = Request(rid=0, prompt=_prompt(1, 6), max_new_tokens=8, arrival=0)
    b = Request(rid=1, prompt=_prompt(2, 3), max_new_tokens=2, arrival=0)
    c = Request(rid=2, prompt=_prompt(3, 4), max_new_tokens=3, arrival=6)

    eng = Engine(session, capacity=2, max_seq=16, admission="continuous")
    alone = eng.run([a]).records[0].tokens
    crowded = eng.run([a, b, c])
    rec = {r.rid: r for r in crowded.records}
    # the scenario really exercised join/retire mid-flight:
    assert rec[1].completed < rec[0].completed     # B retired under A
    assert rec[2].admitted > rec[1].completed      # C recycled B's slot
    assert rec[2].slot == rec[1].slot
    assert rec[0].tokens == alone


def test_per_slot_positions_isolate_ragged_prompts(session):
    """Two requests at different stream offsets in one batch each match
    their solo runs — the (B,)-pos cache is not sharing state."""
    reqs = [Request(rid=0, prompt=_prompt(4, 9), max_new_tokens=4),
            Request(rid=1, prompt=_prompt(5, 2), max_new_tokens=6)]
    eng = Engine(session, capacity=2, max_seq=16, admission="continuous")
    together = {r.rid: r.tokens for r in eng.run(reqs).records}
    for r in reqs:
        solo = eng.run([r]).records[0].tokens
        assert together[r.rid] == solo


# -- lockstep parity with the scalar-cache loop ------------------------------

def test_lockstep_engine_matches_scalar_cache_loop(session):
    """The engine's lockstep mode token-matches the classic serve loop
    (scalar ``pos``, shared prefill-by-token, shared decode)."""
    b, p_len, gen = 3, 5, 4
    prompts = [_prompt(10 + i, p_len) for i in range(b)]
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=gen)
            for i in range(b)]
    eng = Engine(session, capacity=b, max_seq=p_len + gen,
                 admission="lockstep")
    rep = eng.run(reqs)

    # classic loop: one scalar-pos cache, every row in phase
    cache = session.new_cache(b, p_len + gen)
    toks = np.stack(prompts)
    outs = [[] for _ in range(b)]
    last = np.zeros(b, np.int32)
    for t in range(p_len + gen - 1):
        col = toks[:, t] if t < p_len else last
        nxt, cache = session.decode(
            cache, jnp.asarray(col[:, None]),
            session.greedy_positions(b, t))
        last = np.asarray(nxt)[:, 0]  # noqa: ANL002 — reference loop: per-step fetch IS the baseline
        if t >= p_len - 1:
            for i in range(b):
                outs[i].append(int(last[i]))
    assert [r.tokens for r in rep.records] == outs
    assert rep.steps == p_len + gen - 1


# -- the structural scheduling win -------------------------------------------

def test_continuous_needs_fewer_steps_than_lockstep(session):
    reqs = synthetic_requests(7, 10, vocab=256, p_arrive=0.7,
                              prompt_len=(2, 8), gen_len=(2, 10))
    ms = max_seq_for(reqs)
    cont = Engine(session, capacity=3, max_seq=ms,
                  admission="continuous").run(reqs)
    lock = Engine(session, capacity=3, max_seq=ms,
                  admission="lockstep").run(reqs)
    assert cont.steps < lock.steps
    assert cont.slot_utilization > lock.slot_utilization
    # same work either way
    assert cont.generated_tokens == lock.generated_tokens
    assert all(r.completed >= 0 for r in cont.records)
    assert all(r.completed >= 0 for r in lock.records)


def test_arrivals_gate_admission(session):
    """A request is never admitted before its arrival tick, and an idle
    engine fast-forwards to the next arrival instead of spinning."""
    reqs = [Request(rid=0, prompt=_prompt(20, 3), max_new_tokens=2,
                    arrival=0),
            Request(rid=1, prompt=_prompt(21, 3), max_new_tokens=2,
                    arrival=50)]
    rep = Engine(session, capacity=2, max_seq=8,
                 admission="continuous").run(reqs)
    rec = {r.rid: r for r in rep.records}
    assert rec[1].admitted == 50                  # fast-forwarded, not 8
    assert rep.steps == 2 * (3 + 2 - 1)           # no idle burn


# -- plan economy across a run ----------------------------------------------

def test_whole_run_costs_one_encode():
    """Admission certifies via the process plan cache: a multi-request
    run traces ``make_plan`` exactly once per FLGW layer, total."""
    cfg = _tiny_cfg()
    params, _ = transformer.lm_init(jax.random.PRNGKey(0), cfg)
    n_layers = sum(1 for _ in encoder.iter_flgw_layers(params))
    with trace_counter(grouped, "make_plan") as calls:
        sess = ServeSession(cfg, params, plan_policy="certify")
        reqs = synthetic_requests(3, 6, vocab=256, p_arrive=0.6,
                                  prompt_len=(2, 6), gen_len=(2, 6))
        Engine(sess, capacity=2, max_seq=max_seq_for(reqs),
               admission="continuous").run(reqs)
    assert calls.count == n_layers
    assert plan_cache.stats()["encodes"] == 1


# -- slot recycling ----------------------------------------------------------

def test_reset_slots_rewinds_only_masked_rows():
    cfg = registry.get_smoke_config("jamba_1_5_large")   # attn + ssm blocks
    cache = transformer.init_cache(cfg, 3, 8, per_slot=True)
    # dirty every leaf so zeroing is observable
    cache = jax.tree.map(lambda x: jnp.ones_like(x), cache)
    cache["pos"] = jnp.array([5, 3, 7], jnp.int32)

    out = transformer.reset_slots(cache, np.array([False, True, False]))
    np.testing.assert_array_equal(np.asarray(out["pos"]), [5, 0, 7])
    saw_state = False
    for name, blk in out["blocks"].items():
        for leaf in ("state", "conv"):
            if leaf in blk:
                saw_state = True
                got = np.asarray(blk[leaf])
                want = np.asarray(cache["blocks"][name][leaf])
                assert (got[:, 1] == 0).all()              # recycled row
                np.testing.assert_array_equal(got[:, [0, 2]],
                                              want[:, [0, 2]])
        # KV rings ride through untouched — a rewound pos masks them
        for leaf in ("k", "v"):
            if leaf in blk:
                np.testing.assert_array_equal(np.asarray(blk[leaf]),
                                              np.asarray(cache["blocks"]
                                                         [name][leaf]))
    assert saw_state


def test_reset_slots_rejects_scalar_cache():
    cfg = _tiny_cfg()
    cache = transformer.init_cache(cfg, 2, 8)
    with pytest.raises(ValueError, match="per-slot"):
        transformer.reset_slots(cache, np.array([True, False]))


def test_recycled_slot_replays_exactly(session):
    """A prompt served in a freshly reset slot (previously occupied, at a
    different offset) matches the same prompt in a fresh cache — pos
    rewind + state zeroing is a complete recycle."""
    r1 = Request(rid=0, prompt=_prompt(30, 7), max_new_tokens=5)
    r2 = Request(rid=1, prompt=_prompt(31, 4), max_new_tokens=4)
    eng = Engine(session, capacity=1, max_seq=12, admission="continuous")
    rep = eng.run([r1, r2])          # r2 recycles r1's only slot
    solo = eng.run([r2])
    assert rep.records[1].tokens == solo.records[0].tokens
